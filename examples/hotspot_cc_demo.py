"""Deep-dive demo of the paper's concurrency-control engine: workloads,
replication modes, cascading aborts, dynamic batch size.

    PYTHONPATH=src python examples/hotspot_cc_demo.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.lock import (simulate, extract, simulate_aria, extract_aria,
                             WorkloadSpec, CostModel, CSV_HEADER)

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
FIT = WorkloadSpec(kind="fit", txn_len=2, n_rows=4096, n_hot=4)


def table(title, rows):
    print(f"\n=== {title} ===")
    print(CSV_HEADER)
    for r in rows:
        print(r.row())


def main():
    # scalability (Fig 8)
    rows = []
    for proto in ["mysql", "o1", "o2", "bamboo", "group"]:
        for t in (64, 1024):
            rows.append(extract(proto, t, simulate(
                proto, HOT, n_threads=t, horizon=200_000)))
    rows.append(extract_aria(1024, simulate_aria(HOT, 1024,
                                                 horizon=200_000)))
    table("hotspot update scalability (Fig 8)", rows)

    # synchronous replication (Fig 9): TXSQL's 22x
    cm = CostModel(op_exec=500, sync_lat=10_000)
    rows = [extract(p, 256, simulate(p, HOT, n_threads=256,
                                     horizon=3_000_000, costs=cm))
            for p in ["mysql", "group"]]
    table("synchronous replication (Fig 9)", rows)
    print(f"  -> group/mysql = {rows[1].tps / rows[0].tps:.1f}x "
          f"(paper: 22.3x)")

    # cascading aborts (Fig 10)
    r = extract("group", 128, simulate("group", HOT, n_threads=128,
                                       horizon=200_000, p_abort=0.05))
    print(f"\ncascades: {r.user_aborts} injected aborts -> "
          f"{r.forced_aborts} cascaded rollbacks "
          f"({r.forced_aborts / max(r.user_aborts, 1):.1f}x amplification)")

    # hot + non-hot deadlock handling (§4.5)
    r = extract("group", 64, simulate("group", FIT, n_threads=64,
                                      horizon=200_000))
    print(f"FiT hot+non-hot: {r.commits} commits, "
          f"{r.forced_aborts} proactive rollbacks, no deadlock stalls")


if __name__ == "__main__":
    main()
