"""Quickstart: serve an open-system request stream through the lock engine.

    PYTHONPATH=src python examples/serve_quickstart.py

Poisson arrivals -> bounded admission queue -> device-resident engine
pool (``repro.serving``). Two protocols serve the same overload on the
SysBench hotspot, showing the open-system version of the paper's claim:
at high offered load the *protocol* sets the knee, so group locking
completes more requests, rejects fewer, and holds lower tails than
MySQL-style detection 2PL. Exits non-zero if any invariant breaks —
CI runs this as the serving smoke test.
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.lock import WorkloadSpec
from repro.serving import ServeCell, poisson, serve

HOT = WorkloadSpec(kind="hotspot_update", txn_len=2, n_rows=4096)
T = 32
HORIZON = 200_000           # ticks (20 ms simulated)
RATE = 0.01                 # arrivals/tick = 100k offered TPS (overload)


def main():
    print("=== open-system serving: hotspot overload, 32-slot pool ===")
    sched = poisson(RATE, HORIZON, seed=3)
    cells = [
        ServeCell(name=proto, schedule=sched, workload=HOT, n_threads=T,
                  preset=proto, queue_cap=4 * T, admission="reject",
                  max_outstanding=8, sla_us=2_000.0)
        for proto in ("mysql", "group")
    ]
    res = serve(cells, seg_ticks=HORIZON // 20)

    for proto in ("mysql", "group"):
        s = res.serving[proto]
        print(f"  {proto:8s} offered {s.offered_tps:>8.0f} tps | "
              f"goodput {s.goodput_tps:>7.0f} tps | "
              f"p50 {s.p50_us:>7.1f}us p99 {s.p99_us:>7.1f}us | "
              f"rejected {s.rejected:>5d} | "
              f"SLA miss {s.sla_miss_frac:.0%}")

        # request conservation: every arrival is accounted for exactly once
        assert s.arrived == (s.rejected + s.shed + s.completed
                             + s.in_flight_end + s.qlen_end), (
            f"{proto}: conservation violated: {s.arrived} arrived vs "
            f"{s.rejected}+{s.shed}+{s.completed}+{s.in_flight_end}"
            f"+{s.qlen_end}")
        # ... and the per-boundary records sum to the same totals
        recs = res.segments[proto]
        assert sum(r["arrived"] for r in recs) == s.arrived
        assert sum(r["completed"] for r in recs) == s.completed

    m, g = res.serving["mysql"], res.serving["group"]
    # the queue is bounded and the load is an overload: backpressure
    # must actually fire
    assert m.rejected >= 1, "expected backpressure rejections under overload"
    # the knee ordering the figure claims: group locking clears more of
    # the same offered stream than detection 2PL on the hotspot
    assert g.goodput_tps > m.goodput_tps, (
        f"knee ordering violated: group {g.goodput_tps:.0f} <= "
        f"mysql {m.goodput_tps:.0f}")
    assert res.n_compiles <= 1, res.n_compiles

    print(f"  group/mysql goodput: {g.goodput_tps / m.goodput_tps:.2f}x "
          f"({res.n_compiles} compile)")
    print("serve_quickstart: OK")


if __name__ == "__main__":
    main()
