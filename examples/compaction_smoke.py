"""Compaction smoke: mixed-density mini-grid, repack + parity asserted.

Run: PYTHONPATH=src python examples/compaction_smoke.py

The grid packs two churning lanes (mysql/o1 keep committing through
deadlock detection) with six deadlock-stalled ones (o2/group without
detection sit near-idle) into one forced vmap chunk — the straggler mix
the sort-then-cut chunker cannot separate. The compaction scheduler must
(a) repack at least once, (b) cut total vmapped lane-iterations >= 2x,
and (c) stay bit-identical to per-config ``simulate()`` — CI runs this
as the compaction-smoke job.
"""
from repro.core.lock import WorkloadSpec, extract, simulate
from repro.sweep import point, run_sweep

ZIPF = WorkloadSpec(kind="zipf", txn_len=2, n_rows=512, zipf_s=0.9)
HORIZON = 60_000


def main():
    mk = lambda pr, t: point(pr, ZIPF, t, horizon=HORIZON,
                             name=f"{pr}_T{t}")
    pts = [mk("o1", 16), mk("mysql", 16),
           mk("o2", 16), mk("o2", 32), mk("o2", 64),
           mk("group", 16), mk("group", 32), mk("group", 64)]

    res_off = run_sweep(pts, chunk_size=8, compact=False)
    res_on = run_sweep(pts, chunk_size=8)   # compaction: default for G>1

    for p in pts:       # bit-exact vs per-config simulate(), both paths
        s = simulate(p.protocol, p.workload, p.n_threads,
                     horizon=p.horizon)
        ref = extract(p.protocol, p.n_threads, s)
        for res in (res_on, res_off):
            got = res[p.name]
            assert (got.commits, got.iters, got.tps, got.abort_rate) == \
                (ref.commits, ref.iters, ref.tps, ref.abort_rate), p.name
    assert res_on.n_repacks >= 1, res_on.n_repacks
    assert res_off.lane_iters >= 2 * res_on.lane_iters, \
        (res_off.lane_iters, res_on.lane_iters)

    print(f"# compaction smoke OK: lane_iters {res_off.lane_iters} -> "
          f"{res_on.lane_iters} "
          f"({res_off.lane_iters / res_on.lane_iters:.1f}x), "
          f"{res_on.n_repacks} repack(s), wall {res_off.wall_s:.1f}s -> "
          f"{res_on.wall_s:.1f}s")
    for b in res_on.buckets:
        print(f"# repack log (n_live, width, max_delta_iters): "
              f"{b.repack_log}")


if __name__ == "__main__":
    main()
