"""Brook-2PL quickstart: chop analysis -> deadlock-free locking -> sweep.

Run: PYTHONPATH=src python examples/brook_quickstart.py

Brook-2PL (Habibi et al.) lands as ``DynParams`` flags, so the happy
path is the same 3 lines as every other protocol::

    w = WorkloadSpec(kind="zipf", txn_len=4, n_rows=2048, zipf_s=0.9)
    s = simulate("brook2pl", w, n_threads=64, horizon=120_000)
    print(extract("brook2pl", 64, s).tps)

This smoke additionally asserts the protocol's structural claims (used
by the CI ``brook-smoke`` job): zero deadlock-detection ticks, zero
deadlock (forced) rollbacks, a drained system with the serializability
counter invariant intact, and a win over mysql-2PL in the deadlock
regime — then shows the ``chop`` analysis the ordering comes from and
a bit-exact brook sweep lane.
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core.lock import (HALT, WorkloadSpec, chop, extract, simulate)
from repro.sweep import grid, run_sweep

W = WorkloadSpec(kind="zipf", txn_len=4, n_rows=2048, zipf_s=0.9)
TPCC = WorkloadSpec(kind="tpcc", txn_len=10, n_rows=8192,
                    n_warehouses=4, write_ratio=0.6)
T = 64
HORIZON = 120_000


def main():
    # 1. the static analysis Brook-2PL runs on (per workload template)
    print(chop.chop(TPCC).describe())
    print()

    # 2. deadlock regime head-to-head: multi-row writes on a hot zipf set
    results = {}
    for proto in ("mysql", "brook2pl"):
        s = simulate(proto, W, n_threads=T, horizon=HORIZON, drain=True)
        r = extract(proto, T, s)
        results[proto] = r
        print(f"{proto:9s} tps={r.tps:9.0f} deadlock_aborts="
              f"{r.forced_aborts} dd_ticks={r.dd_ticks}")
        leftover = int(jnp.abs(s.rows.applied_val
                               - s.rows.committed_val).sum())
        assert bool((s.th.phase == HALT).all()), f"{proto}: did not drain"
        assert leftover == 0, f"{proto}: serializability violated"

    b, m = results["brook2pl"], results["mysql"]
    assert b.forced_aborts == 0, "brook2pl rolled back a deadlock victim"
    assert b.dd_ticks == 0, "brook2pl paid deadlock-detection ticks"
    assert b.commits > m.commits, "brook2pl must beat mysql under skew"
    print(f"# brook2pl/mysql commits: {b.commits / max(m.commits, 1):.2f}x,"
          " zero deadlock aborts, zero detection ticks")

    # 3. the sweep substrate carries brook2pl like any other protocol —
    #    vmapped lanes stay bit-identical to the simulate() calls above
    pts = grid(["mysql", "brook2pl"], W, T, horizon=HORIZON, drain=True,
               name_fmt="{protocol}_T{n_threads}")
    res = run_sweep(pts, chunk_size=2)
    for proto in ("mysql", "brook2pl"):
        got = res[f"{proto}_T{T}"]
        want = results[proto]
        assert (got.commits, got.iters, got.tps, got.dd_ticks) == \
            (want.commits, want.iters, want.tps, want.dd_ticks), proto
    print(f"# sweep parity ok ({res.n_compiles} compile(s) this run)")
    print("brook-quickstart-ok")


if __name__ == "__main__":
    main()
