"""Quickstart: the paper's result in ~a minute, plus a tiny training run.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.lock import simulate, extract, WorkloadSpec
from repro.configs import get_config
from repro.models import lm_spec, init_params
from repro.optim import adamw
from repro.data import DataConfig, init_state, make_batch
from repro.launch.steps import make_train_step


def cc_demo():
    print("=== TXSQL group locking vs baselines "
          "(SysBench hotspot update, 256 threads) ===")
    w = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
    base = None
    for proto in ["mysql", "o1", "o2", "bamboo", "group"]:
        r = extract(proto, 256,
                    simulate(proto, w, n_threads=256, horizon=200_000))
        base = base or r.tps
        tag = {"group": "TXSQL (group locking)"}.get(proto, proto)
        print(f"  {tag:24s} {r.tps:>9.0f} TPS   "
              f"({r.tps / base:4.1f}x MySQL)")


def train_demo(steps=20):
    print("\n=== 20 training steps, qwen2-0.5b (smoke config) ===")
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(lm_spec(cfg), jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                decay_steps=steps)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    ds, dc = init_state(), DataConfig()
    for i in range(steps):
        batch, ds = make_batch(dc, cfg, 8, 64, ds)
        params, opt, m = step(params, opt, batch)
        if i % 5 == 0 or i == steps - 1:
            print(f"  step {i:3d}  loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    cc_demo()
    train_demo()
