"""Trace quickstart: capture a lock-event trace, check the tick books
balance, and export a Perfetto-viewable Chrome trace.

    PYTHONPATH=src python examples/trace_quickstart.py [out.json]

What this demonstrates (DESIGN.md §11):

1. ``simulate_traced`` — the same engine step, but every grant /
   wait-enter / timeout / deadlock-victim / early-release / group-join /
   commit is appended to a fixed-allocation on-device ring buffer from
   inside the ``lax.while_loop``. Capacity and the on/off switch are
   traced *data*, so tracing never recompiles, and ``trace_on=False`` is
   bit-exact with the untraced engine (checked below).
2. Tick conservation — the engine charges every thread-tick to exactly
   one TickBreakdown bin, so the bins sum to ``padded_T x elapsed``
   (asserted; this is the invariant tests/test_obs.py enforces).
3. Export — Chrome trace-event JSON. Open the output file at
   https://ui.perfetto.dev (or chrome://tracing): each worker thread is
   a track, lock waits are spans named after the contended row, commits
   and deadlock victims are instant markers. Zoom into the hottest rows
   from the wait-profile printed below and you can watch mysql's
   wait-die queue churn thread by thread.
4. Overflow semantics — a deliberately tiny capacity: the buffer keeps
   its earliest events intact and counts the rest in ``dropped`` (the
   profile then says it is a lower bound) instead of wrapping.
5. Blame (DESIGN.md §14) — the same event stream pairs each wait span
   with the transaction attempt *holding* the row, yielding a blame
   table (who caused the queueing on each hot record) and the longest
   blocking chain; the export grows per-row queue-depth counter lanes.
"""
import json
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.lock import WorkloadSpec, simulate, extract
from repro.obs import (EV_VICTIM, blame_table, check_conservation,
                       critical_path, dump_chrome_trace, events_host,
                       make_trace, simulate_traced, wait_profile)

# zipf with multi-op transactions: lock-order cycles actually form, so
# mysql's detection walk has victims to kill (hotspot_update txn_len=1
# cannot deadlock — single-lock transactions never cycle)
WL = WorkloadSpec(kind="zipf", txn_len=4, n_rows=2048, zipf_s=0.9)
T, HORIZON = 64, 120_000


def main(out_path="trace_quickstart.json"):
    print(f"=== tracing mysql on zipf(s=0.9) x{T} threads, "
          f"{HORIZON} ticks ===")
    s, tb = simulate_traced("mysql", WL, n_threads=T, horizon=HORIZON,
                            cap=65_536)
    r = extract("mysql", T, s)

    # 1. the books balance: every tick of every (padded) thread is in
    # exactly one breakdown bin
    pad_t = int(s.th.phase.shape[0])
    check_conservation(s, pad_t)
    total = sum(r.breakdown.values())
    print(f"tick conservation: sum(breakdown) = {total} "
          f"= {pad_t} threads x {total // pad_t} ticks  OK")
    print("breakdown:", {k: v for k, v in r.breakdown.items() if v})

    # 2. the trace saw real contention, including deadlock victims
    ev = events_host(tb)
    n_victims = int(np.sum(ev["ev"][:ev["n"]] == EV_VICTIM))
    print(f"events: {ev['n']} stored, {ev['dropped']} dropped, "
          f"{n_victims} deadlock victims, {r.commits} commits")
    assert n_victims >= 1, "expected deadlock victims under mysql/zipf"

    # 3. export for Perfetto (with top-4 hotspot queue-depth counter
    # lanes) and sanity-check the JSON round-trips
    dump_chrome_trace(out_path, ev, label="mysql zipf quickstart",
                      hotspot_lanes=4)
    with open(out_path) as f:
        doc = json.load(f)
    assert doc["traceEvents"], "empty trace"
    assert all("ph" in e and "ts" in e for e in doc["traceEvents"]
               if e["ph"] != "M")
    print(f"wrote {out_path} ({len(doc['traceEvents'])} trace events) — "
          "open it at https://ui.perfetto.dev")

    print("\n" + wait_profile(ev, top_k=8))

    # 3b. blame: pair every wait span with the holding transaction
    # attempt — who to kill, not just where it hurts — plus the longest
    # blocking chain threading through the capture
    end = int(s.g.now)
    print("\n" + blame_table(ev, top_k=8, end=end))
    path = critical_path(ev, end=end)
    if path:
        hops = " -> ".join(f"t{h['tid']}@r{h['row']}" for h in path[:6])
        print(f"critical path: {len(path)} hops, "
              f"{sum(h['dur'] for h in path)} blocked ticks: {hops}")

    # 4. overflow: a 64-event buffer on the same run keeps its first 64
    # events bit-identical to the big capture and counts the rest
    _, tb_small = simulate_traced("mysql", WL, n_threads=T,
                                  horizon=HORIZON, cap=64, alloc=65_536)
    ev_s = events_host(tb_small)
    assert ev_s["n"] == 64 and ev_s["dropped"] > 0
    for col in ("ts", "tid", "row", "ev"):
        assert np.array_equal(ev_s[col], ev[col][:64]), col
    print(f"\noverflow demo: cap=64 kept the first 64 events intact, "
          f"dropped {ev_s['dropped']}")

    # 5. trace_on=False is the stock engine, bit for bit
    s_off, _ = simulate_traced("mysql", WL, n_threads=T, horizon=HORIZON,
                               cap=65_536, trace_on=False)
    s_ref = simulate("mysql", WL, n_threads=T, horizon=HORIZON)
    for a, b in zip(jax_leaves(s_off), jax_leaves(s_ref)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    print("trace_on=False parity with simulate(): bit-exact  OK")


def jax_leaves(tree):
    import jax
    return jax.tree_util.tree_leaves(tree)


if __name__ == "__main__":
    main(*sys.argv[1:2])
