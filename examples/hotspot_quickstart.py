"""Hotspot attribution quickstart: find the records that own the wait,
name the transactions that caused it, and expose it all as live metrics.

    PYTHONPATH=src python examples/hotspot_quickstart.py [metrics.prom]

What this demonstrates (DESIGN.md §14):

1. ``simulate(..., attrib=True)`` — the engine carries a per-record
   contention accumulator (``Globals.ca``: wait ticks, grants, timeouts,
   victims, queue depth) updated inside the ``lax.while_loop``. The flag
   is traced data: flipping it never recompiles, and off-runs are
   bit-exact with the stock engine.
2. Conservation — the accumulator's wait ticks sum to the TickBreakdown's
   lock_wait bin *exactly* (both charge the same mask at the same tick),
   so the per-record ranking is a lossless decomposition of a number the
   engine already reports.
3. ``hotspot_report`` — top-K records by wait share, the Gini coefficient
   of the wait distribution, and its amplification over the zipf access
   distribution's own skew (how much the *protocol* concentrates
   contention beyond the access pattern).
4. Blame — an event trace of the same cell pairs each wait span with the
   holding transaction attempt: the blame table and critical blocking
   chain (``obs.blame``).
5. Live serving metrics — a served pool with ``attrib=True`` feeds a
   Prometheus-text-exposition registry per boundary
   (``serving.ServingMetrics``); top-K hotspot gauges ride along, and
   the exposition is scrape-able over HTTP or dumped textfile-style.
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import urllib.request

from repro.core.lock import WorkloadSpec, simulate
from repro.obs import (blame_table, check_ca_conservation, events_host,
                       hotspot_report, simulate_traced)
from repro.serving import ServeCell, ServingMetrics, poisson, serve

WL = WorkloadSpec(kind="zipf", txn_len=8, n_rows=2048, zipf_s=1.2)
T, HORIZON = 64, 120_000


def main(out_path="hotspot_metrics.prom"):
    # 1+2: accumulator on, conservation exact
    print(f"=== mysql on zipf(s=1.2) x{T} threads, {HORIZON} ticks, "
          "attrib=True ===")
    s = simulate("mysql", WL, n_threads=T, horizon=HORIZON, attrib=True)
    check_ca_conservation(s)
    print("conservation: sum(ca.wait_ticks) == breakdown[lock_wait]  OK\n")

    # 3: where does the wait concentrate, and who concentrated it?
    print(hotspot_report(s, WL, top_k=8))

    # 4: the blame view of the same cell (event-trace pairing)
    s_tr, tb = simulate_traced("mysql", WL, n_threads=T, horizon=HORIZON,
                               cap=65_536, attrib=True)
    ev = events_host(tb)
    print("\n" + blame_table(ev, top_k=6, end=int(s_tr.g.now)))

    # 5: live metrics from a served pool
    reg = ServingMetrics(sla_budget=0.01, top_k=4)
    cell = ServeCell(name="pool", schedule=poisson(0.004, 60_000, seed=7),
                     workload=WL, n_threads=16, preset="mysql",
                     sla_us=500.0, attrib=True)
    serve([cell], seg_ticks=10_000, metrics_registry=reg)
    srv = reg.serve_http()          # port 0 -> pick a free one
    port = srv.server_address[1]
    scraped = urllib.request.urlopen(
        f"http://127.0.0.1:{port}/metrics").read().decode()
    srv.shutdown()
    assert scraped == reg.render()
    reg.dump(out_path)
    hot = [ln for ln in scraped.splitlines()
           if ln.startswith("repro_hotspot_wait_ticks{")]
    print(f"\nserving metrics: scraped {len(scraped.splitlines())} "
          f"exposition lines from :{port}, wrote {out_path}")
    for ln in hot:
        print("  " + ln)


if __name__ == "__main__":
    main(*sys.argv[1:2])
