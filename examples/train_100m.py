"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpointing, resume, hotspot-grouped embedding updates, and
straggler/heartbeat monitoring.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs.base import ModelConfig
import repro.configs as configs
from repro.launch import train as train_mod

# ~100M params: 640 width, 8 layers, GQA 8/4
CONFIG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    layout=(((("global", "dense"),), 8),),
    d_model=640,
    n_heads=8,
    n_kv_heads=4,
    d_ff=2560,
    vocab=32_000,
    head_dim=80,
    vocab_pad_to=128,
    remat=False,
    source="examples/train_100m.py",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    print(f"params ~= {CONFIG_100M.param_count() / 1e6:.0f}M")
    # register as a transient arch so the driver can pick it up
    mod = dataclasses.make_dataclass("M", [])()
    mod.CONFIG = CONFIG_100M
    mod.SMOKE = CONFIG_100M
    configs._MODULES["repro-100m"] = mod

    losses = train_mod.train(
        "repro-100m", smoke=False, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=100)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps)")


if __name__ == "__main__":
    main()
