"""Sweep-subsystem quickstart: a whole protocol x threads grid in 3 lines.

Run: PYTHONPATH=src python examples/sweep_quickstart.py

The grid below (4 protocols x 3 thread counts over the paper's hotspot
workload) is bucketed by shape, padded, and executed as shared-compile
batched JAX programs; results are bit-identical to calling ``simulate()``
once per point. Swap in ``expand()`` for workload-field axes (Zipf skew,
write ratio), add ``p_abort=[...]`` / ``costs=[...]`` axes, or
``save_results()`` to keep a JSON record — see repro/sweep/.
"""
from repro.core.lock import WorkloadSpec
from repro.sweep import grid, run_sweep, summarize, save_results

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)


def main():
    # The 3-line sweep: grid -> run_sweep -> summarize.
    pts = grid(["mysql", "o2", "group", "bamboo"], HOT, [16, 64, 256],
               horizon=100_000)
    res = run_sweep(pts)
    print("\n".join(summarize(res)))

    print(f"# {len(pts)} configs, {res.n_compiles} engine compile(s), "
          f"{res.wall_s:.1f}s wall")
    save_results("/tmp/sweep_quickstart.json", res,
                 meta={"example": "sweep_quickstart"})
    print("# results JSON -> /tmp/sweep_quickstart.json")


if __name__ == "__main__":
    main()
