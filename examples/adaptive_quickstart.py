"""Adaptive governor quickstart: drift schedule -> policy -> run -> summary.

Successor of the old hotspot_cc_demo, rewired onto the governor API
(``repro.adaptive``). The happy path is three lines::

    drift = skew_ramp(WorkloadSpec(kind="zipf", txn_len=4, n_rows=4096), 8)
    res = run_governed([GovernorCell("adaptive", QueueRulePolicy(), drift,
                                     n_threads=64, costs=CM)],
                       horizon=120_000, n_segments=8)
    print(summarize(res))

    PYTHONPATH=src python examples/adaptive_quickstart.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.adaptive import (EpsilonGreedyPolicy, FixedPolicy, GovernorCell,
                            QueueRulePolicy, preset_timeline, run_governed)
from repro.core.lock import CostModel, WorkloadSpec, skew_ramp
from repro.sweep import save_results, summarize

CM = CostModel(op_exec=20, commit_base=30)   # lock-manager-bound OLTP


def main():
    # 1. build a drift schedule: Zipf skew ramps across the run, crossing
    #    the deadlock valley where detection-free protocols stall
    base = WorkloadSpec(kind="zipf", txn_len=4, n_rows=4096)
    drift = skew_ramp(base, 8, lo=0.3, hi=0.7)

    # 2. pick policies: the paper's queue rule, a greedy searcher, and
    #    fixed-protocol baselines riding the same segmented substrate
    cells = [
        GovernorCell("adaptive_rule", QueueRulePolicy(), drift, 64,
                     costs=CM),
        GovernorCell("adaptive_greedy", EpsilonGreedyPolicy(), drift, 64,
                     costs=CM),
        GovernorCell("fixed_mysql", FixedPolicy("mysql"), drift, 64,
                     costs=CM),
        GovernorCell("fixed_o2", FixedPolicy("o2"), drift, 64, costs=CM),
    ]

    # 3. run governed (one engine compile for all cells and segments)
    res = run_governed(cells, horizon=120_000, n_segments=8)

    print("name,us_per_call,derived")
    for row in summarize(res):
        print(row)
    print(f"# {len(cells)} cells x 8 segments, "
          f"{res.n_compiles} engine compile(s)")
    for name in ("adaptive_rule", "adaptive_greedy"):
        print(f"# {name} timeline: {' -> '.join(preset_timeline(res, name))}")

    out = os.environ.get("ADAPTIVE_QUICKSTART_JSON",
                         "/tmp/adaptive_quickstart.json")
    save_results(out, res, meta={"example": "adaptive_quickstart"})
    print(f"# per-segment records written to {out} (repro.sweep/v3)")


if __name__ == "__main__":
    main()
