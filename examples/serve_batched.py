"""Batched serving with TXSQL-style dynamic group commit (§4.6.1).

    PYTHONPATH=src python examples/serve_batched.py --requests 16
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
