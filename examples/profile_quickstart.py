"""Profiler quickstart: where does an engine iteration's wall time go?

    PYTHONPATH=src python examples/profile_quickstart.py

What this demonstrates (DESIGN.md §12):

1. ``profile_step`` — the stage-ablation step profiler. The engine step
   is one fused XLA program inside a ``lax.while_loop``; no span-based
   profiler can see inside it, so each stage (dup analysis, deadlock
   walk, ticket grant, commit-cursor derivation, group/hotspot branches,
   tick charging) is instead *ablated* — replaced by a stand-in XLA
   dead-code-eliminates — and the steady-state per-iteration wall of the
   ablated executable is differenced against the full step on the same
   warmed ``SimState``. One executable per ablation, compile counts
   asserted, and the stand-ins are bit-exact no-ops under designated
   configs (tests/test_prof.py), so the difference is the stage's cost.
2. The ranked table — ``commit_cursor`` (the T*L -> R segment
   reductions in ``_derive``) dominates on the paper's hotspot shape:
   that scan is the fusion target for the ROADMAP's "Pallas-kernel the
   engine hot path" item, and the profiler is how we'll know the kernel
   actually moved it.
3. Compile telemetry — ``obs.compile_log`` counts the XLA backend wall
   these executables cost, attributed per function name.
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.lock import (CostModel, EngineConfig, WorkloadSpec,
                             protocol_params)
from repro.obs import compile_log, profile_step, rank_table

WL = WorkloadSpec(kind="hotspot_update", txn_len=4, n_rows=512)


def main():
    tele0 = compile_log.snapshot()
    for proto in ("mysql", "brook2pl"):
        cfg = EngineConfig(protocol=protocol_params(proto),
                           costs=CostModel(), workload=WL,
                           n_threads=64, horizon=2_000_000)
        prof = profile_step(cfg, n_iters=64, repeats=2)
        print(rank_table(prof))
        assert abs(sum(s.fraction for s in prof.stages) - 1.0) < 1e-9
        assert prof.compiles == len(prof.stages)   # stages + other - full
        print()
    tele = compile_log.delta(tele0)
    slow = sorted(tele["fns"].items(), key=lambda kv: -kv[1]["secs"])[:3]
    print(f"compile telemetry: {tele['compile_time_s']:.1f}s XLA wall over "
          f"{tele['backend_compiles']} backend compiles; slowest: "
          + ", ".join(f"{n} {r['secs']:.1f}s" for n, r in slow))


if __name__ == "__main__":
    main()
