"""Analysis quickstart: lint one entry point for trace leaks, watch the
linter catch a planted leak, and certify a real schedule serializable.

    PYTHONPATH=src python examples/analysis_quickstart.py

What this demonstrates (DESIGN.md §13):

1. ``jaxpr_lint.lint_entry`` — the twice-lowering oracle. The engine's
   scalar entry is built twice with configs differing in EVERY value
   (timeouts, costs, zipf skew, abort rate, ...) at identical shapes;
   byte-identical jaxprs certify that no knob is constant-folded into
   the executable, i.e. one compile really serves every config.
2. The negative control — a wrapper with the exact bug the linter
   exists for (``int(cfg.protocol.wait_timeout)`` folded into a closure
   before the jit boundary). The linter must FAIL it; a linter that
   passes the planted bug measures nothing.
3. ``isolation.certify_run`` — run the traced engine and certify the
   schedule it actually executed: conflict-serializability from the
   write-write dependency graph, strict-2PL hold discipline for mysql,
   and zero dirty reads even with injected aborts.
4. Brook-2PL's chop-piece mode — txn-level ww cycles are the *expected*
   signature of transaction chopping, so the certifier proves
   serializability at piece granularity (mutually exclusive hold
   intervals + ascending-rank acquisition) instead, and reports the
   txn cycles as informational.
5. A synthetically cyclic trace is REJECTED with the concrete cycle.
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import cli as acli
from repro.analysis import isolation, jaxpr_lint
from repro.core.lock import WorkloadSpec

WL = WorkloadSpec(kind="zipf", n_rows=256, txn_len=4, zipf_s=1.1, seed=1)


def main():
    # 1. lint the engine's scalar entry (run_lint() does all 14; one is
    # enough to show the shape of a finding-free report)
    ep = next(e for e in jaxpr_lint.default_entry_points()
              if e.name == "engine._run_dyn")
    findings = jaxpr_lint.lint_entry(ep)
    print(f"lint {ep.name}: "
          f"{'clean' if not findings else [str(f) for f in findings]}")
    assert not findings

    # 2. the planted leak must be caught
    bad = jaxpr_lint.lint_entry(jaxpr_lint.leaky_entry_point())
    assert any(f.rule in ("value-leak", "static-leak") for f in bad)
    print(f"planted leak: caught as [{bad[-1].rule}]")

    # 3. certify mysql with injected aborts: acyclic ww graph, strict
    # 2PL holds, no dirty edges
    c = isolation.certify_run("mysql", WL, 16, horizon=40_000,
                              p_abort=0.05, seed=1,
                              **acli.TIMEOUT_OVER)
    print("\n" + c.text())
    assert c.ok and c.mode == "txn-ww" and not c.dirty_edges

    # 4. brook2pl certifies at piece granularity; txn-level cycles are
    # the documented chopping signature, not a bug
    cb = isolation.certify_run("brook2pl", WL, 16, horizon=40_000,
                               p_abort=0.05, seed=1)
    print("\n" + cb.text())
    assert cb.ok and cb.mode == "chop-piece" and cb.chop_ww_cycles

    # 5. and the certifier can say no
    bad_cert = isolation.certify(acli.cyclic_events(), "mysql")
    print(f"\nsynthetic cycle: serializable={bad_cert.serializable} "
          f"cycle={bad_cert.cycle}")
    assert not bad_cert.ok and bad_cert.cycle is not None

    print("\nanalysis quickstart: all checks passed")


if __name__ == "__main__":
    main()
