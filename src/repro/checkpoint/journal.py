"""Step journal: the ``hot_update_order`` persistence of §5.3, adapted.

An append-only JSONL ledger of checkpoint attempts. Each save ASSIGNS a
monotone order (the dependency-list append), then COMMITS it only after the
atomic rename (commit order == assign order, enforced by DependencyList).
Restore reads the latest committed entry; uncommitted (crashed) attempts
are simply absent — re-running recovery is idempotent.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.core.dependency import DependencyList


class Journal:
    def __init__(self, path: str):
        self.path = path
        self._dep = DependencyList()
        self._committed: dict[int, int] = {}    # step -> order
        self._load()

    def _load(self):
        if not os.path.exists(self.path):
            return
        open_orders = []
        max_order = -1
        with open(self.path) as f:
            for line in f:
                if not line.strip():
                    continue
                rec = json.loads(line)
                max_order = max(max_order, rec["order"])
                if rec["event"] == "assign":
                    open_orders.append(rec["order"])
                elif rec["event"] == "commit":
                    if rec["order"] in open_orders:
                        open_orders.remove(rec["order"])
                    self._committed[rec["step"]] = rec["order"]
        # crash recovery: uncommitted assigns are rolled back in reverse
        # order (the paper's reverse hot_update_order replay)
        self._dep.recover(open_orders)
        for o in sorted(open_orders, reverse=True):
            self._dep.rollback(o)
        self._dep.bump(max_order + 1)

    def _append(self, rec):
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def assign(self, step: int) -> int:
        order = self._dep.assign()
        self._append({"event": "assign", "step": step, "order": order})
        return order

    def commit(self, step: int, order: int):
        self._dep.commit(order)
        self._append({"event": "commit", "step": step, "order": order})
        self._committed[step] = order

    def latest_committed(self) -> Optional[int]:
        return max(self._committed) if self._committed else None

    def committed_steps(self):
        return sorted(self._committed)
