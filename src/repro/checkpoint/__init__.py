from .checkpointer import Checkpointer
from .journal import Journal

__all__ = ["Checkpointer", "Journal"]
