"""Sharded, async, two-phase-commit checkpointing.

Maps the paper's durability machinery onto the training loop:
  * 2PC (§4.3): a checkpoint is written to ``step_N.tmp-*`` (Prepare:
    binlog flush/sync), then committed by a single atomic directory rename
    (Commit). A crash between phases leaves only tmp garbage, which restore
    ignores — exactly the binlog/redo consistency argument.
  * group commit: one manifest covers every array shard; the commit is one
    rename regardless of shard count.
  * ``hot_update_order`` persistence (§5.3): the journal (journal.py)
    records the monotone step order; restore replays the latest *committed*
    entry, and a crash during restore is idempotent.

Arrays are stored as one ``.npz`` per host shard plus a JSON manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from concurrent.futures import ThreadPoolExecutor, Future
from typing import Any, Optional

import numpy as np
import jax

from .journal import Journal


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, host_id: int = 0, async_save=True):
        self.dir = directory
        self.host_id = host_id
        os.makedirs(directory, exist_ok=True)
        self.journal = Journal(os.path.join(directory, "journal.jsonl"))
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[Future] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Two-phase save; async unless blocking."""
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        order = self.journal.assign(step)

        def work():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-",
                                   dir=self.dir)
            try:
                np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"),
                         *host_leaves)
                manifest = {
                    "step": step,
                    "order": order,
                    "n_leaves": len(host_leaves),
                    "hosts": 1,
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                # ---- Commit phase: single atomic rename ----
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self.journal.commit(step, order)
            except Exception:
                shutil.rmtree(tmp, ignore_errors=True)
                raise

        if self._pool is not None and not blocking:
            self.wait()                       # keep commit order (dep list)
            self._pending = self._pool.submit(work)
        else:
            work()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        return self.journal.latest_committed()

    def restore(self, step: Optional[int], like: Any) -> Any:
        """Restore into the structure (and shardings) of `like`."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoint")
        final = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(final, f"shard_{self.host_id}.npz"))
        leaves = [data[k] for k in data.files]
        like_leaves, treedef = _flatten(like)
        assert len(leaves) == len(like_leaves), \
            f"checkpoint has {len(leaves)} leaves, expected " \
            f"{len(like_leaves)}"
        out = []
        for arr, ref in zip(leaves, like_leaves):
            val = jax.numpy.asarray(arr, dtype=ref.dtype)
            if hasattr(ref, "sharding") and ref.sharding is not None:
                try:
                    val = jax.device_put(val, ref.sharding)
                except Exception:
                    pass
            out.append(val)
        return jax.tree_util.tree_unflatten(treedef, out)

    def gc(self, keep: int = 3):
        steps = self.journal.committed_steps()
        for s in steps[:-keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
