from .pipeline import DataConfig, DataState, init_state, make_batch

__all__ = ["DataConfig", "DataState", "init_state", "make_batch"]
