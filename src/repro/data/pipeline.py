"""Deterministic synthetic data pipeline (per-host sharded, checkpointable).

Token streams are Zipf-distributed (so embedding-row hotspots are *real* in
training benchmarks — the paper's skewed-access assumption holds for the
adapted technique too). Every batch is a pure function of
(seed, host, step): restart at step k reproduces batch k exactly, which is
what makes checkpoint/restart and elastic re-sharding deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.lock.workload import zipf_cdf


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_s: float = 1.0          # natural-language-like token skew
    n_hosts: int = 1
    host_id: int = 0


class DataState(NamedTuple):
    step: jnp.ndarray            # () i32 — the only mutable state


def init_state() -> DataState:
    return DataState(step=jnp.zeros((), jnp.int32))


def _fold(dc: DataConfig, step) -> jax.Array:
    key = jax.random.PRNGKey(dc.seed)
    key = jax.random.fold_in(key, dc.host_id)
    return jax.random.fold_in(key, step)


def make_batch(dc: DataConfig, cfg, batch: int, seq: int, state: DataState):
    """Synthesize one LM batch for this host. Returns (batch_dict, state)."""
    key = _fold(dc, state.step)
    kt, ke, kp = jax.random.split(key, 3)
    out = {}
    if cfg.embed_inputs:
        u = jax.random.uniform(kt, (batch, seq + 1))
        cdf = jnp.asarray(zipf_cdf(cfg.vocab, dc.zipf_s))
        toks = jnp.searchsorted(cdf, u).astype(jnp.int32)
        toks = jnp.clip(toks, 0, cfg.vocab - 1)
        out["tokens"] = toks[:, :seq]
        out["labels"] = toks[:, 1:]
    else:
        out["embeds"] = jax.random.normal(
            ke, (batch, seq, cfg.d_model), jnp.bfloat16)
        if cfg.n_codebooks:
            out["labels"] = jax.random.randint(
                kt, (batch, seq, cfg.n_codebooks), 0, cfg.vocab, jnp.int32)
        else:
            out["labels"] = jax.random.randint(
                kt, (batch, seq), 0, cfg.vocab, jnp.int32)
    if cfg.mrope:
        base = jnp.arange(seq, dtype=jnp.int32)[None, None]
        out["positions3"] = jnp.broadcast_to(base, (3, batch, seq))
    return out, DataState(step=state.step + 1)
