"""Serializability certifier over lock-engine event traces.

Consumes a :class:`repro.obs.trace.TraceBuf` (or its ``events_host``
dict) and certifies, per run, that the schedule the engine actually
executed is conflict-serializable and honors each protocol's locking
discipline — the paper's §6.5 claim ("all six protocols produce
serializable schedules") checked on real schedules rather than asserted
from the design.

**Why this works without a read log**: in this engine only writes take
tickets (``need_ticket = begin & bwr``; reads are snapshot reads off the
committed-value array and never enqueue), so every ``grant`` /
``group_join`` event is a *write* acquisition and the conflict graph is
the write-write graph. Snapshot reads cannot create rw/wr anomalies
against in-flight writers because they read only committed state.

**Derivation** (:func:`dependency_graph`):

* The buffer position ``seq`` is the authoritative order: the buffer is
  appended time-ordered by construction, and within one iteration the
  blocks land t_pre-first with tids ascending, which resolves dt=0 ticks
  and same-iteration group co-grants deterministically.
* Per thread, events partition into *attempts* at ``commit`` / ``abort``
  terminators (``timeout`` and ``deadlock_victim`` are decisions — the
  attempt ends only when the rollback completes, i.e. at ``abort``).
* Per row, the committed attempts' acquisitions in ``seq`` order form a
  chain; consecutive distinct attempts give a ww edge. Consecutive
  pairs generate the same reachability as all pairs (the per-row order
  is total), so cycle detection over them is exact.
* An edge is ``ww-uncommitted`` when the successor acquired before the
  predecessor's commit landed — only possible under early release /
  group locking / per-op release, and forbidden for the strict-2PL
  protocols.

**Per-protocol certification mode**: protocols that hold write locks to
commit (or cascade dependents on abort) must produce an acyclic
txn-level ww graph — that is what ``serializable`` certifies for mysql /
o1 / o2 / group / bamboo. Brook-2PL is different *by design*: transaction
chopping releases each row at its last use, so txn-level ww cycles are
expected (two chopped txns can touch shared rows in opposite ticket
order) and benign — the engine's writes are commutative counter
increments, and chopping theory + commutativity is the protocol's
serializability argument, not 2PL. For ``per_op_release`` protocols the
certifier therefore proves the *chopped* execution serializable at piece
granularity: every per-row hold interval is mutually exclusive (checked,
not assumed), all conflict edges then follow the global piece order
(acyclic by construction), acquisition is ascending-rank, and no dirty
windows exist. Txn-level ww cycles are still counted and reported
(``chop_ww_cycles``) as the documented, expected signature of chopping.
* A *dirty edge* is a committed successor acquiring a row inside an
  aborted predecessor's (acquire, abort] window — it may have built on
  state that was then reverted. The engine's commit-order discipline +
  cascading aborts claim this never happens; the certifier proves it on
  the trace (exercised with injected ``p_abort`` in tests).

**Caveats**: a trace with ``dropped > 0`` yields a *lower-bound*
certificate (the checked prefix is certified; the tail is unobserved) —
``Certificate.lower_bound`` says so. Malformed buffers (out-of-range
event ids, time-travel timestamps, counters off) are rejected before
any certification (``input-invalid`` violations).
"""
from __future__ import annotations

import dataclasses

from repro.core.lock.costs import ProtocolParams, protocol_params
from repro.obs.trace import (EV_ABORT, EV_COMMIT, EV_GRANT, EV_GROUP_JOIN,
                             EV_RELEASE, EV_TIMEOUT, EV_VICTIM,
                             EV_WAIT_ENTER, EVENTS, TraceBuf, events_host)

_ACQUIRE = (EV_GRANT, EV_GROUP_JOIN)
_TERMINAL = (EV_COMMIT, EV_ABORT)


def _as_events(trace_or_events) -> dict:
    if isinstance(trace_or_events, TraceBuf):
        return events_host(trace_or_events)
    return trace_or_events


@dataclasses.dataclass
class Attempt:
    """One transaction attempt: a thread's events up to a terminator."""
    tid: int
    idx: int                      # per-thread attempt ordinal
    terminator: str               # "commit" | "abort" | "open"
    end_seq: int = -1             # seq of the terminator event
    end_ts: int = -1
    # acquisitions in seq order: (seq, ts, row, ev)
    acquires: list = dataclasses.field(default_factory=list)
    # (seq, ts, row) lists
    releases: list = dataclasses.field(default_factory=list)
    wait_enters: list = dataclasses.field(default_factory=list)
    timeouts: int = 0
    victims: int = 0

    @property
    def key(self) -> tuple:
        return (self.tid, self.idx)


@dataclasses.dataclass(frozen=True)
class Edge:
    pred: tuple                   # Attempt.key
    succ: tuple
    row: int
    kind: str                     # "ww" | "ww-uncommitted"


@dataclasses.dataclass
class Certificate:
    protocol: str
    mode: str                     # "txn-ww" | "chop-piece"
    serializable: bool
    n_attempts: int
    n_committed: int
    n_aborted: int
    n_open: int
    n_edges: int
    cycle: list | None            # attempt keys forming a cycle, if any
    chop_ww_cycles: bool          # chop mode: txn-level ww cycle exists
                                  # (expected + benign; informational)
    dirty_edges: list             # (aborted_key, committed_key, row)
    violations: list              # human-readable rule violations
    lower_bound: bool             # True when the trace dropped events

    @property
    def ok(self) -> bool:
        return self.serializable and not self.dirty_edges \
            and not self.violations

    def text(self) -> str:
        head = (f"{self.protocol} [{self.mode}]: "
                f"attempts={self.n_attempts} "
                f"(committed={self.n_committed} aborted={self.n_aborted} "
                f"open={self.n_open}) ww_edges={self.n_edges}")
        lines = [head]
        if self.mode == "chop-piece" and self.chop_ww_cycles:
            lines.append("  note: txn-level ww cycles present — expected "
                         "under chopping; serializability holds at piece "
                         "granularity + commutative writes")
        if self.lower_bound:
            lines.append("  NOTE: trace dropped events — certificate "
                         "covers the stored prefix only (lower bound)")
        if self.cycle:
            lines.append(f"  CYCLE: {' -> '.join(map(str, self.cycle))}")
        for p, s, row in self.dirty_edges[:10]:
            lines.append(f"  DIRTY: committed {s} acquired row {row} "
                         f"inside aborted {p}'s abort window")
        lines.extend(f"  VIOLATION: {v}" for v in self.violations[:10])
        lines.append("  " + ("CERTIFIED conflict-serializable"
                             if self.ok else "REJECTED"))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# input validation — a certifier that trusts a corrupt buffer certifies
# nothing, so malformed traces are rejected up front (negative-tested).
# ---------------------------------------------------------------------------

def validate_events(ev: dict) -> list:
    problems = []
    n = int(ev["n"])
    if n < 0 or n > len(ev["ts"]):
        return [f"input-invalid: n={n} outside stored arrays"]
    if int(ev.get("dropped", 0)) < 0:
        problems.append("input-invalid: negative dropped counter")
    last_ts = None
    for i in range(n):
        e = int(ev["ev"][i])
        t = int(ev["ts"][i])
        if not 0 <= e < len(EVENTS):
            problems.append(f"input-invalid: event id {e} at seq {i} "
                            f"outside EVENTS")
            break
        if t < 0:
            problems.append(f"input-invalid: negative tick {t} at seq {i}")
            break
        if last_ts is not None and t < last_ts:
            problems.append(f"input-invalid: time travel at seq {i} "
                            f"({last_ts} -> {t}); buffer must be "
                            f"time-ordered")
            break
        last_ts = t
    return problems


# ---------------------------------------------------------------------------
# attempts + graph
# ---------------------------------------------------------------------------

def attempts_from_events(ev: dict) -> list:
    """Partition the buffer into per-thread attempts (see module doc)."""
    cur: dict = {}
    done: list = []

    def _get(tid: int) -> Attempt:
        if tid not in cur:
            n_prev = sum(1 for a in done if a.tid == tid)
            cur[tid] = Attempt(tid=tid, idx=n_prev, terminator="open")
        return cur[tid]

    counts: dict = {}
    for i in range(int(ev["n"])):
        tid, row, e, t = (int(ev["tid"][i]), int(ev["row"][i]),
                          int(ev["ev"][i]), int(ev["ts"][i]))
        a = _get(tid)
        if e in _ACQUIRE:
            a.acquires.append((i, t, row, e))
        elif e == EV_RELEASE:
            a.releases.append((i, t, row))
        elif e == EV_WAIT_ENTER:
            a.wait_enters.append((i, t, row))
        elif e == EV_TIMEOUT:
            a.timeouts += 1
        elif e == EV_VICTIM:
            a.victims += 1
        elif e in _TERMINAL:
            a.terminator = EVENTS[e]
            a.end_seq, a.end_ts = i, t
            done.append(a)
            counts[tid] = counts.get(tid, 0) + 1
            del cur[tid]
    done.extend(cur.values())     # still-open attempts at capture end
    return done


def dependency_graph(attempts: list) -> tuple:
    """(nodes, edges, dirty) over committed attempts; see module doc."""
    committed = {a.key: a for a in attempts if a.terminator == "commit"}
    aborted = [a for a in attempts if a.terminator == "abort"]

    # per-row acquisition chains, committed attempts only, in seq order
    chains: dict = {}
    for a in attempts:
        if a.terminator != "commit":
            continue
        for seq, ts, row, _e in a.acquires:
            chains.setdefault(row, []).append((seq, ts, a))
    edges: list = []
    for row, chain in chains.items():
        chain.sort()
        for (ps, _pt, pa), (ss, _st, sa) in zip(chain, chain[1:]):
            if pa.key == sa.key:
                continue
            kind = "ww-uncommitted" if ss < pa.end_seq else "ww"
            edges.append(Edge(pred=pa.key, succ=sa.key, row=row,
                              kind=kind))

    # dirty edges: committed attempt acquired a row inside an aborted
    # attempt's (acquire, abort] seq window
    dirty: list = []
    for p in aborted:
        for pseq, _pt, row, _e in p.acquires:
            for a in committed.values():
                for sseq, _st, srow, _se in a.acquires:
                    if srow == row and pseq < sseq <= p.end_seq:
                        dirty.append((p.key, a.key, row))
    return committed, edges, dirty


def find_cycle(nodes: dict, edges: list):
    """Kahn's algorithm; on leftovers, walk successors to extract one
    concrete cycle for the report. Returns None when acyclic."""
    adj: dict = {k: [] for k in nodes}
    indeg = {k: 0 for k in nodes}
    for e in edges:
        if e.pred in adj and e.succ in indeg:
            adj[e.pred].append(e.succ)
            indeg[e.succ] += 1
    queue = [k for k, d in indeg.items() if d == 0]
    seen = 0
    while queue:
        k = queue.pop()
        seen += 1
        for s in adj[k]:
            indeg[s] -= 1
            if indeg[s] == 0:
                queue.append(s)
    if seen == len(nodes):
        return None
    # Leftovers are the nodes on or downstream of cycles; every leftover
    # has a leftover PREDECESSOR (not necessarily a successor), so walk
    # the reversed graph and flip the found loop back into edge order.
    rest = {k for k, d in indeg.items() if d > 0}
    radj: dict = {k: [] for k in rest}
    for e in edges:
        if e.pred in rest and e.succ in rest:
            radj[e.succ].append(e.pred)
    start = min(rest)
    path, where = [start], {start: 0}
    while True:
        nxt = next(p for p in radj[path[-1]] if p in rest)
        if nxt in where:
            loop = path[where[nxt]:] + [nxt]
            return loop[::-1]
        where[nxt] = len(path)
        path.append(nxt)


# ---------------------------------------------------------------------------
# protocol-discipline checks
# ---------------------------------------------------------------------------

def _strict_2pl_violations(attempts: list, edges: list,
                           committed: dict) -> list:
    """Strict 2PL: locks hold to commit. No early-release events may
    fire, and every ww successor acquires at-or-after the predecessor's
    commit tick (equality allowed: t_post of iteration k IS t_pre of
    iteration k+1)."""
    out = []
    n_rel = sum(len(a.releases) for a in attempts)
    if n_rel:
        out.append(f"strict-2pl: {n_rel} early_release event(s) under a "
                   f"hold-to-commit protocol")
    for e in edges:
        if e.kind == "ww-uncommitted":
            out.append(f"strict-2pl: {e.succ} acquired row {e.row} "
                       f"before {e.pred} committed")
            continue
        pred = committed[e.pred]
        succ = committed[e.succ]
        ts = next(t for _s, t, r, _e in succ.acquires if r == e.row)
        if ts < pred.end_ts:
            out.append(f"strict-2pl: {e.succ} acquired row {e.row} at "
                       f"tick {ts} < {e.pred} commit tick {pred.end_ts}")
    return out


def _hold_violations(attempts: list) -> list:
    """Piece-level mutual exclusion: per row, a holder's interval
    [grant seq, release-or-terminator seq] never overlaps the next
    holder's grant. This is the checked premise that makes the chopped
    execution's conflict edges follow the global piece order (and hence
    the piece graph acyclic). Open attempts without a release contribute
    only their grant (their end is unobserved)."""
    per_row: dict = {}
    for a in attempts:
        rel_by_row: dict = {}
        for seq, _t, row in a.releases:
            rel_by_row.setdefault(row, []).append(seq)
        for gseq, _t, row, _e in a.acquires:
            rels = [s for s in rel_by_row.get(row, []) if s > gseq]
            end = min(rels) if rels else \
                (a.end_seq if a.terminator != "open" else None)
            per_row.setdefault(row, []).append((gseq, end, a.key))
    out = []
    for row, holds in per_row.items():
        holds.sort()
        for (g1, e1, k1), (g2, _e2, k2) in zip(holds, holds[1:]):
            if e1 is not None and g2 < e1:
                out.append(f"mutual-exclusion: row {row} granted to "
                           f"{k2} at seq {g2} while {k1} held it until "
                           f"seq {e1}")
    return out


def _rank_violations(attempts: list, acq_rank) -> list:
    """Brook-2PL: rows are requested in non-decreasing chop rank within
    an attempt (checked on wait_enter order, which is request order)."""
    out = []
    ranks = list(acq_rank)
    for a in attempts:
        reqs = sorted(a.wait_enters)
        rs = [int(ranks[row]) for _s, _t, row in reqs
              if 0 <= row < len(ranks)]
        bad = [i for i in range(1, len(rs)) if rs[i] < rs[i - 1]]
        if bad:
            out.append(f"brook-rank: attempt {a.key} requested ranks "
                       f"{rs} — descends at position {bad[0]}")
    return out


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def certify(trace_or_events, protocol: str | ProtocolParams,
            acq_rank=None) -> Certificate:
    """Certify one run's schedule. ``protocol`` picks the discipline
    checks (a name from PROTOCOLS or explicit params); ``acq_rank`` is
    the chop-rank table (DynWorkload.acq_rank) for ordered-acquire
    protocols."""
    ev = _as_events(trace_or_events)
    pp = (protocol if isinstance(protocol, ProtocolParams)
          else protocol_params(protocol))
    mode = "chop-piece" if pp.per_op_release else "txn-ww"
    problems = validate_events(ev)
    if problems:
        return Certificate(
            protocol=pp.name, mode=mode, serializable=False,
            n_attempts=0, n_committed=0, n_aborted=0, n_open=0,
            n_edges=0, cycle=None, chop_ww_cycles=False, dirty_edges=[],
            violations=problems, lower_bound=bool(ev.get("dropped", 0)))
    attempts = attempts_from_events(ev)
    committed, edges, dirty = dependency_graph(attempts)
    cycle = find_cycle(committed, edges)
    violations = []
    strict = not (pp.early_release or pp.early_all or pp.per_op_release
                  or pp.group_lock)
    if strict:
        violations += _strict_2pl_violations(attempts, edges, committed)
    if pp.ordered_acquire and acq_rank is not None:
        violations += _rank_violations(attempts, acq_rank)
    if mode == "chop-piece":
        # txn-level cycles are the expected chopping signature; the
        # certified claim is piece-level (see module doc)
        violations += _hold_violations(attempts)
        serializable = not any(v.startswith("mutual-exclusion")
                               for v in violations)
        chop_cycles, cycle = cycle is not None, None
    else:
        serializable = cycle is None
        chop_cycles = False
    return Certificate(
        protocol=pp.name, mode=mode, serializable=serializable,
        n_attempts=len(attempts),
        n_committed=len(committed),
        n_aborted=sum(1 for a in attempts if a.terminator == "abort"),
        n_open=sum(1 for a in attempts if a.terminator == "open"),
        n_edges=len(edges), cycle=cycle, chop_ww_cycles=chop_cycles,
        dirty_edges=dirty, violations=violations,
        lower_bound=bool(ev.get("dropped", 0)))


def certify_run(protocol: str, workload, n_threads: int,
                horizon: int = 40_000, p_abort: float = 0.0,
                seed: int = 0, cap: int = 65_536,
                **proto_over) -> Certificate:
    """Run the traced engine and certify the resulting schedule."""
    from repro.core.lock.engine import EngineConfig, split_config
    from repro.core.lock.costs import CostModel
    from repro.obs.trace import simulate_traced
    _s, tb = simulate_traced(protocol, workload, n_threads,
                             horizon=horizon, p_abort=p_abort, seed=seed,
                             cap=cap, **proto_over)
    cfg = EngineConfig(protocol=protocol_params(protocol, **proto_over),
                       costs=CostModel(), workload=workload,
                       n_threads=n_threads, horizon=horizon,
                       p_abort=p_abort, seed=seed)
    _stat, dp = split_config(cfg)
    rank = dp.wl.acq_rank if protocol_params(protocol).ordered_acquire \
        else None
    return certify(tb, protocol_params(protocol, **proto_over),
                   acq_rank=None if rank is None else list(map(int, rank)))


def total_trace_wait_ticks(trace_or_events, enders=(EV_GRANT, EV_TIMEOUT,
                                                    EV_VICTIM)) -> int:
    """Sum of resolved wait spans (wait_enter -> grant/timeout/victim)
    across all threads. Unresolved waits and dropped events only shrink
    the sum, so this is a sound lower bound on engine lock-wait ticks
    (property-tested against the TickBreakdown lock_wait bin)."""
    ev = _as_events(trace_or_events)
    open_by_tid: dict = {}
    total = 0
    for i in range(int(ev["n"])):
        tid, e, t = int(ev["tid"][i]), int(ev["ev"][i]), int(ev["ts"][i])
        if e == EV_WAIT_ENTER:
            open_by_tid[tid] = t
        elif e in enders and tid in open_by_tid:
            total += t - open_by_tid.pop(tid)
    return total
