"""Analysis report runner: ``python -m repro.analysis.cli``.

Runs the trace-leak linter over every registered entry point and the
serializability certifier over a protocol × seed × workload matrix,
prints a combined report, and exits non-zero on any finding — the CI
analysis-gate job is exactly this command.

``--selftest`` additionally proves the tools can fail: the deliberately
leaky entry point must FAIL the lint, and synthetically cyclic /
corrupted traces must be REJECTED by the certifier. A linter that
passes everything including the planted bug is measuring nothing, so
the selftest is part of the gate, not an option left for curiosity.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.lock.costs import PROTOCOLS
from repro.core.lock.workload import WorkloadSpec

from . import isolation, jaxpr_lint

# certifier matrix defaults; timeouts shortened so the detection-free
# protocols resolve deadlocks inside the short horizon (brook2pl keeps
# its protocol-defining timeout=0)
KINDS = ("zipf", "tpcc", "hotspot_update")
SEEDS = (1, 2, 3)
HORIZON = 40_000
THREADS = 16
TIMEOUT_OVER = dict(wait_timeout=8_000, commit_wait_timeout=8_000)


def _workload(kind: str, seed: int) -> WorkloadSpec:
    if kind == "tpcc":
        return WorkloadSpec(kind="tpcc", n_rows=256, txn_len=4,
                            n_warehouses=4, seed=seed)
    if kind == "hotspot_update":
        return WorkloadSpec(kind="hotspot_update", n_rows=256, txn_len=4,
                            n_hot=4, seed=seed)
    return WorkloadSpec(kind="zipf", n_rows=256, txn_len=4, zipf_s=1.1,
                        seed=seed)


def run_certify_matrix(kinds=KINDS, seeds=SEEDS, p_abort: float = 0.05,
                       verbose: bool = True) -> list:
    certs = []
    for proto in PROTOCOLS:
        over = {} if proto == "brook2pl" else dict(TIMEOUT_OVER)
        for kind in kinds:
            for seed in seeds:
                c = isolation.certify_run(
                    proto, _workload(kind, seed), THREADS,
                    horizon=HORIZON, p_abort=p_abort, seed=seed, **over)
                certs.append((kind, seed, c))
                if verbose:
                    ok = "ok  " if c.ok else "FAIL"
                    print(f"{ok} {proto:<9} {kind:<15} seed={seed} "
                          f"committed={c.n_committed} "
                          f"aborted={c.n_aborted} edges={c.n_edges}")
                    if not c.ok:
                        print(c.text())
    return certs


# ---------------------------------------------------------------------------
# selftest fixtures: traces the certifier must reject
# ---------------------------------------------------------------------------

def cyclic_events() -> dict:
    """Two committed attempts acquiring rows 1 and 2 in opposite orders:
    ww edges A->B (row 1) and B->A (row 2) — a conflict cycle no 2PL
    schedule can produce."""
    from repro.obs.trace import EV_COMMIT, EV_GRANT
    ev = [(0, 0, 1, EV_GRANT), (0, 1, 2, EV_GRANT),
          (5, 1, 1, EV_GRANT), (5, 0, 2, EV_GRANT),
          (9, 0, -1, EV_COMMIT), (9, 1, -1, EV_COMMIT)]
    return {"ts": np.array([e[0] for e in ev]),
            "tid": np.array([e[1] for e in ev]),
            "row": np.array([e[2] for e in ev]),
            "ev": np.array([e[3] for e in ev]),
            "n": len(ev), "dropped": 0, "cap": len(ev)}


def corrupted_events() -> dict:
    """Time-travelling buffer (ts not monotone) with a rogue event id."""
    ev = cyclic_events()
    ev["ts"] = np.array([0, 5, 3, 5, 9, 9])     # 5 -> 3 travels back
    ev["ev"] = ev["ev"].copy()
    ev["ev"][4] = 99                            # outside EVENTS
    return ev


def run_selftest(verbose: bool = True) -> list:
    fails = []
    lf = jaxpr_lint.lint_entry(jaxpr_lint.leaky_entry_point())
    if not any(f.rule in ("value-leak", "static-leak") for f in lf):
        fails.append("selftest: leaky entry point PASSED the lint")
    cyc = isolation.certify(cyclic_events(), "mysql")
    if cyc.serializable or cyc.ok:
        fails.append("selftest: cyclic trace was certified serializable")
    bad = isolation.certify(corrupted_events(), "mysql")
    if bad.ok or not any("input-invalid" in v for v in bad.violations):
        fails.append("selftest: corrupted trace was not rejected")
    if verbose:
        print(f"selftest: leaky-entry lint "
              f"{'caught' if not fails else 'see failures'}; cyclic "
              f"trace {'rejected' if not cyc.serializable else 'MISSED'};"
              f" corrupted trace "
              f"{'rejected' if not bad.ok else 'MISSED'}")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--no-lint", action="store_true")
    ap.add_argument("--no-certify", action="store_true")
    ap.add_argument("--selftest", action="store_true",
                    help="also run the must-fail negative controls")
    ap.add_argument("--quick", action="store_true",
                    help="1 seed / 2 kinds certifier matrix")
    args = ap.parse_args(argv)
    failures = 0

    if not args.no_lint:
        t0 = time.time()
        rep = jaxpr_lint.run_lint()
        print(rep.text())
        print(f"# lint wall: {time.time() - t0:.1f}s")
        failures += len(rep.findings)

    if not args.no_certify:
        t0 = time.time()
        kinds = KINDS[:2] if args.quick else KINDS
        seeds = SEEDS[:1] if args.quick else SEEDS
        certs = run_certify_matrix(kinds=kinds, seeds=seeds)
        bad = [c for _k, _s, c in certs if not c.ok]
        print(f"# certify: {len(certs) - len(bad)}/{len(certs)} runs "
              f"certified, wall: {time.time() - t0:.1f}s")
        failures += len(bad)

    if args.selftest:
        st = run_selftest()
        for s in st:
            print(s)
        failures += len(st)

    print("analysis: " + ("PASS" if failures == 0 else
                          f"FAIL ({failures} failure(s))"))
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
