"""Correctness analysis subsystem (DESIGN.md §13).

Two static/dynamic certifiers over the lock engine:

* :mod:`.jaxpr_lint` — the trace-leak linter: lowers every registered
  jitted entry point twice with value-only config variants and certifies
  the jaxprs byte-identical (no knob constant-folded into the program),
  plus rule walks over the jaxpr (no host callbacks / 64-bit values /
  weak floats in the hot loop, scatter mode discipline, protocol-branch
  count vs registry).
* :mod:`.isolation` — the serializability certifier: consumes TraceBuf
  event streams and proves each run's schedule conflict-serializable
  under its protocol's discipline (txn-level ww acyclicity, or piece
  level for chopped protocols), strict-2PL hold rules, Brook ascending
  ranks, and dirty-read freedom under injected aborts.

``python -m repro.analysis.cli`` runs both as a report; the CI
analysis-gate job fails the build on any finding.
"""
from . import isolation, jaxpr_lint
from .isolation import (Attempt, Certificate, Edge, attempts_from_events,
                        certify, certify_run, dependency_graph, find_cycle,
                        total_trace_wait_ticks, validate_events)
from .jaxpr_lint import (EntryPoint, LintFinding, LintReport,
                         PROTOCOL_COND_SITES, default_entry_points,
                         leaky_entry_point, lint_entry, run_lint)

__all__ = [
    "isolation", "jaxpr_lint",
    "Attempt", "Certificate", "Edge", "attempts_from_events", "certify",
    "certify_run", "dependency_graph", "find_cycle",
    "total_trace_wait_ticks", "validate_events",
    "EntryPoint", "LintFinding", "LintReport", "PROTOCOL_COND_SITES",
    "default_entry_points", "leaky_entry_point", "lint_entry", "run_lint",
]
