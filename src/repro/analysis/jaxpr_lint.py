"""Static trace-leak linter: certify that every knob stays traced.

The repo's perf story rests on one invariant (DESIGN.md §3): the compile
key is *shapes only* — every protocol flag, cost constant, workload
parameter, and run knob is a traced jnp leaf, so one executable serves
every config at a shape. Nothing has enforced it statically until now; a
single careless ``float(dp.x)`` in a wrapper silently forks the
executable cache and invalidates every sweep/compaction/serving number.

**The leak oracle is twice-lowering.** Each registered entry point is
built twice from the *config level* (EngineConfig / AriaConfig / raw
arrays) with variant configs that differ in EVERY value-like leaf while
agreeing in every shape, then lowered with ``jax.make_jaxpr``. Because
traced arguments are abstracted to avals, a knob's *value* can reach the
jaxpr text only by leaking:

* a builder folded it into the static part (``StaticShape`` mismatch —
  caught by direct equality before lowering);
* a wrapper closed over a Python scalar computed from the config before
  the jit boundary (a constant in the jaxpr — caught by the byte-diff);
* the traced code concretized it (``int(dp.x)`` / ``if dp.x:`` — raises
  ``ConcretizationTypeError`` at lowering, reported as a finding);
* its dtype/weak-type depends on its value (aval text diff).

Byte-identical jaxprs across the two variants therefore certify "no
knob is constant-folded anywhere on this entry point's build path". On
a mismatch the linter bisects leaf-by-leaf and names the offending leaf
path(s).

**Rule walks** (over the variant-A jaxpr, recursing into cond/while/pjit
sub-jaxprs):

* ``callbacks``  — no host/io/debug callback primitive inside a
  ``while`` body: a host round-trip per tick-loop iteration is a
  100-1000x slowdown and deadlocks under donated buffers.
* ``wide-dtype`` — no 64-bit value (f64/i64/u64/c128) inside a ``while``
  body: an accidental x64 promotion doubles hot-loop bandwidth (the
  engine is memory-bound at AI ~ 0.6, DESIGN.md §12).
* ``weak-float`` — no weakly-typed *float* inside a ``while`` body: a
  Python float literal riding the hot loop is the classic source of
  silent f32->f64 promotion once x64 is enabled. (Weak i32/bool are the
  normal residue of integer literals and stay allowed.)
* ``scatter-mode`` — no scatter-family eqn may resolve to
  ``PROMISE_IN_BOUNDS`` (out-of-bounds writes become UB). Note
  ``mode=None`` resolves to FILL_OR_DROP in the jaxpr, byte-identical
  to an explicit ``mode="drop"`` — "has an explicit mode=" is not
  checkable post-lowering, so the rule checks the resolved semantics
  instead. Gathers are exempt: plain ``x[idx]`` lowers to
  PROMISE_IN_BOUNDS gathers and the engine pre-clips every index.
* ``cond-count`` — the number of ``cond`` primitives matches the
  protocol-branch registry (:data:`PROTOCOL_COND_SITES`): a runtime-
  skippable protocol branch that silently becomes unconditional compute
  (or a new Python-level protocol fork) changes this count.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax._src import core as _jcore
from jax.lax import GatherScatterMode

from repro.core.lock import aria as A
from repro.core.lock import engine as E
from repro.core.lock.costs import (CostModel, PROTOCOLS, protocol_params)
from repro.core.lock.engine import EngineConfig, I32, split_config
from repro.core.lock.workload import WorkloadSpec
from repro.obs import trace as obs_trace

# ---------------------------------------------------------------------------
# cond-site registry: every lax.cond in the engine step, gated by a
# ProtocolParams flag (the PROTOCOLS table in costs.py is the source of
# truth for which flags exist) or a run knob (``contention_attrib`` is
# gated by ``EngineConfig.attrib`` / ``DynParams.attrib``, the per-record
# contention accumulator — DESIGN.md §14).
# ---------------------------------------------------------------------------

PROTOCOL_COND_SITES = {
    "deadlock_walk": "has_detection",
    "group_lock": "group_lock",
    "group_commit": "group_commit",
    "hotspot_detect": "hot_queue",
    "contention_attrib": "attrib",
}

_FORBIDDEN_IN_WHILE = ("pure_callback", "io_callback", "debug_callback",
                       "callback", "outside_call", "host_callback_call")
_WIDE_DTYPES = ("float64", "int64", "uint64", "complex128")
_SCATTER_PRIMS = ("scatter", "scatter-add", "scatter-mul", "scatter-min",
                  "scatter-max", "scatter-apply")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    entry: str
    rule: str
    detail: str

    def __str__(self) -> str:
        return f"{self.entry}: [{self.rule}] {self.detail}"


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One lintable jitted entry point.

    ``build(variant)`` returns ``(static_args, dyn_args)`` built from a
    variant config; variants 0 and 1 must differ in every value-like
    leaf and agree in every shape. ``fn`` is the jitted wrapper (lowered
    via ``__wrapped__`` with the static prefix marked static).
    ``cond_count`` pins the expected number of ``cond`` primitives
    (None = not checked; vmapped entries lower conds to selects).
    """
    name: str
    fn: Callable
    build: Callable[[int], tuple[tuple, tuple]]
    cond_count: int | None = None
    expect_while: bool = True


# ---------------------------------------------------------------------------
# variant config builders — every value-like field differs between variants
# at identical shapes. Bools flip with the variant parity.
# ---------------------------------------------------------------------------

_SHAPE = dict(kind="zipf", n_rows=64, txn_len=2, n_threads=8)


def _flip(i: int) -> bool:
    # lane-wise parity that differs between variant (i, i+2) pairs, so
    # batched builds also flip every bool per lane: 0,1,2,3 -> F,T,T,F
    return bool((i ^ (i >> 1)) & 1)


def _workload(i: int) -> WorkloadSpec:
    return WorkloadSpec(
        kind=_SHAPE["kind"], n_rows=_SHAPE["n_rows"],
        txn_len=_SHAPE["txn_len"],
        write_ratio=0.7 + 0.1 * i, zipf_s=0.6 + 0.1 * i, n_hot=2 + i,
        n_warehouses=1 + i, seed=11 + i, reads_lock=_flip(i),
        hot_base=i)


def _engine_cfg(i: int) -> EngineConfig:
    b = _flip(i)
    proto = protocol_params(
        "mysql",
        lock_base=10 + i, grant_cost=2 + i, dd_coeff=3.0 + 0.25 * i,
        has_detection=not b, hot_queue=b, early_release=b, early_all=b,
        group_lock=b, group_commit=b, dynamic_batch=not b,
        batch_size=8 + i, hot_threshold=16 + i, proactive_abort=b,
        ordered_acquire=b, per_op_release=b,
        wait_timeout=400_000 + i, commit_wait_timeout=300_000 + i)
    costs = CostModel(
        op_exec=50 + i, read_exec=20 + i, commit_base=100 + i,
        sync_lat=10 + i, rb_base=80 + i, rb_per_op=40 + i,
        backoff=200 + i, queue_insert=3 + i, arrival_rate=0.01 * (i + 1),
        rb_turn_timeout=20_000 + i)
    return EngineConfig(
        protocol=proto, costs=costs, workload=_workload(i),
        n_threads=_SHAPE["n_threads"], horizon=10_000 + i,
        p_abort=0.02 * (i + 1), drain=b, max_iters=900_000 + i,
        seed=5 + i, attrib=not b)


def _split(i: int):
    stat, dp = split_config(_engine_cfg(i))
    return stat, dp


def _build_run_dyn(v: int):
    stat, dp = _split(v)
    return (stat,), (dp, E.init_state_dyn(stat, dp))


def _build_run_batch(v: int):
    stat0, dp0 = _split(2 * v)
    stat1, dp1 = _split(2 * v + 1)
    assert stat0 == stat1
    dps = jax.tree.map(lambda a, b: jnp.stack([a, b]), dp0, dp1)
    s0 = E.init_state_dyn(stat0, dp0)
    s0s = jax.tree.map(lambda x: jnp.stack([x, x]), s0)
    return (stat0,), (dps, s0s)


def _build_run_seg_dyn(v: int):
    stat, dp = _split(v)
    return (stat,), (dp, E.init_state_dyn(stat, dp),
                     jnp.asarray(5_000 + v, I32))


def _build_run_seg_batch(v: int):
    (stat,), (dps, s0s) = _build_run_batch(v)
    untils = jnp.asarray([4_000 + v, 6_000 + v], I32)
    return (stat,), (dps, s0s, untils)


def _aria_cfg(i: int) -> A.AriaConfig:
    costs = CostModel(op_exec=50 + i, commit_base=100 + i, sync_lat=5 + i)
    return A.AriaConfig(workload=_workload(i), costs=costs,
                        n_threads=_SHAPE["n_threads"], horizon=10_000 + i)


def _build_aria_dyn(v: int):
    stat, dp = A.split_aria(_aria_cfg(v))
    return (stat,), (dp,)


def _build_aria_batch(v: int):
    s0, d0 = A.split_aria(_aria_cfg(2 * v))
    s1, d1 = A.split_aria(_aria_cfg(2 * v + 1))
    assert s0 == s1
    return (s0,), (jax.tree.map(lambda a, b: jnp.stack([a, b]), d0, d1),)


def _build_aria_seg_dyn(v: int):
    stat, dp = A.split_aria(_aria_cfg(v))
    return (stat,), (dp, A.init_aria_state(stat),
                     jnp.asarray(5_000 + v, I32))


def _build_aria_seg_batch(v: int):
    (stat,), (dps,) = _build_aria_batch(v)
    s0 = A.init_aria_state(stat)
    s0s = jax.tree.map(lambda x: jnp.stack([x, x]), s0)
    return (stat,), (dps, s0s, jnp.asarray([4_000 + v, 6_000 + v], I32))


def _build_traced(v: int):
    stat, dp = _split(v)
    tb = obs_trace.make_trace(cap=32 + v, alloc=64, on=_flip(v))
    return (stat,), (dp, E.init_state_dyn(stat, dp), tb,
                     jnp.asarray(7_000 + v, I32))


def _build_hist_add(v: int):
    from repro.serving import runner as S
    hist = jnp.full((E.N_HIST,), v, I32)
    ticks = jnp.arange(16, dtype=I32) * (v + 1)
    valid = jnp.arange(16) % 2 == (v % 2)
    return (), (hist, ticks, valid)


def _kernel_arrays(v: int, shape):
    n = 1
    for d in shape:
        n *= d
    base = jnp.arange(n, dtype=jnp.float32).reshape(shape)
    return base * (0.01 * (v + 1)) + v


def _build_flash(v: int):
    q = _kernel_arrays(v, (1, 2, 16, 8))
    k = _kernel_arrays(v + 4, (1, 2, 16, 8))
    vv = _kernel_arrays(v + 8, (1, 2, 16, 8))
    return (), (q, k, vv)


def _build_grouped_scatter(v: int):
    table = _kernel_arrays(v, (32, 8))
    ids = (jnp.arange(64, dtype=I32) * (v + 3)) % 32
    updates = _kernel_arrays(v + 2, (64, 8))
    return (), (table, ids, updates)


def _build_segment_sums(v: int):
    seg_ids = (jnp.arange(64, dtype=I32) * (v + 3)) % 16
    updates = _kernel_arrays(v, (64, 8))
    return (), (seg_ids, updates)


def default_entry_points() -> list[EntryPoint]:
    """Every registered jitted entry point (mirrors the compile_log
    registry — keep the two in sync; tested in tests/test_analysis.py)."""
    n_cond = len(PROTOCOL_COND_SITES)
    eps = [
        EntryPoint("engine._run_dyn", E._run_dyn, _build_run_dyn,
                   cond_count=n_cond),
        EntryPoint("engine._run_batch", E._run_batch, _build_run_batch),
        EntryPoint("engine._run_seg_dyn", E._run_seg_dyn,
                   _build_run_seg_dyn, cond_count=n_cond),
        EntryPoint("engine._run_seg_batch", E._run_seg_batch,
                   _build_run_seg_batch),
        EntryPoint("aria._run_dyn", A._run_dyn, _build_aria_dyn),
        EntryPoint("aria._run_batch", A._run_batch, _build_aria_batch),
        EntryPoint("aria._run_seg_dyn", A._run_seg_dyn,
                   _build_aria_seg_dyn),
        EntryPoint("aria._run_seg_batch", A._run_seg_batch,
                   _build_aria_seg_batch),
        EntryPoint("trace._run_traced", obs_trace._run_traced,
                   _build_traced, cond_count=n_cond),
    ]
    from repro.serving import runner as S
    eps.append(EntryPoint("serving._hist_add", S._hist_add,
                          _build_hist_add, expect_while=False))
    try:    # Pallas-backed entry points; optional on exotic hosts
        from repro.kernels.flash_attention import kernel as fk, ops as fo
        from repro.kernels.grouped_scatter import kernel as gk, ops as go

        def _segment_sums_g16(seg_ids, updates):
            # num_groups is a shape argument, fixed like the other shapes
            return gk.segment_sums(seg_ids, updates, 16)

        eps += [
            EntryPoint("kernels.flash_attention", fo.flash_attention,
                       _build_flash, expect_while=False),
            EntryPoint("kernels.flash_attention_bhsd",
                       fk.flash_attention_bhsd, _build_flash,
                       expect_while=False),
            EntryPoint("kernels.grouped_scatter_apply",
                       go.grouped_scatter_apply, _build_grouped_scatter,
                       expect_while=False),
            EntryPoint("kernels.segment_sums", _segment_sums_g16,
                       _build_segment_sums, expect_while=False),
        ]
    except Exception:
        pass
    return eps


# ---------------------------------------------------------------------------
# lowering + diffing
# ---------------------------------------------------------------------------

def _lower(ep: EntryPoint, static: tuple, dyn: tuple):
    fn = getattr(ep.fn, "__wrapped__", ep.fn)
    statics = tuple(range(len(static)))
    return jax.make_jaxpr(fn, static_argnums=statics)(*static, *dyn)


def _text(jaxpr) -> str:
    """Canonical comparable text: jaxpr body PLUS const values.

    A closure-folded knob becomes a ``ClosedJaxpr`` const, which the
    jaxpr body renders as an anonymous constvar — byte-identical across
    variants. The leak lives in the const *value*, so it must be part of
    the compared text (hashed, to keep big tables cheap)."""
    import hashlib
    import numpy as np
    parts = [str(jaxpr)]
    for c in jaxpr.consts:
        a = np.asarray(c)
        parts.append(f"const {a.dtype}{a.shape} "
                     f"{hashlib.sha256(a.tobytes()).hexdigest()}")
    return "\n".join(parts)


def _leaf_paths(args: tuple) -> list[tuple]:
    leaves, _ = jax.tree_util.tree_flatten_with_path(args)
    return [p for p, _ in leaves]


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _bisect_leak(ep: EntryPoint, static: tuple, a: tuple, b: tuple,
                 base_text: str, limit: int = 8) -> list[str]:
    """Name the leaf path(s) whose value changes the lowered program."""
    la, tda = jax.tree_util.tree_flatten_with_path(a)
    lb, _ = jax.tree_util.tree_flatten_with_path(b)
    offenders = []
    for k, ((path, xa), (_, xb)) in enumerate(zip(la, lb)):
        flat = [x for _, x in la]
        flat[k] = xb
        mixed = jax.tree_util.tree_unflatten(tda, flat)
        try:
            txt = _text(_lower(ep, static, mixed))
        except Exception as e:
            offenders.append(f"{_path_str(path)} (lowering raised "
                             f"{type(e).__name__})")
            continue
        if txt != base_text:
            offenders.append(_path_str(path))
        if len(offenders) >= limit:
            offenders.append("... (bisect stopped)")
            break
    return offenders


# ---------------------------------------------------------------------------
# jaxpr walking + rules
# ---------------------------------------------------------------------------

def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for x in vals:
            if isinstance(x, _jcore.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, _jcore.Jaxpr):
                yield x


def _walk(jaxpr, inside_while: bool, visit) -> None:
    for eqn in jaxpr.eqns:
        visit(eqn, inside_while)
        inner = inside_while or eqn.primitive.name == "while"
        for sub in _sub_jaxprs(eqn):
            _walk(sub, inner, visit)


def _rule_findings(ep: EntryPoint, jaxpr) -> list[LintFinding]:
    out: list[LintFinding] = []
    counts = {"cond": 0, "while": 0}

    def visit(eqn, in_while):
        name = eqn.primitive.name
        if name in counts:
            counts[name] += 1
        if in_while:
            if name in _FORBIDDEN_IN_WHILE:
                out.append(LintFinding(ep.name, "callbacks",
                                       f"`{name}` inside a while body — "
                                       f"host round-trip per iteration"))
            for v in eqn.outvars:
                dt = str(getattr(v.aval, "dtype", ""))
                if dt in _WIDE_DTYPES:
                    out.append(LintFinding(
                        ep.name, "wide-dtype",
                        f"`{name}` produces {dt} inside a while body"))
                elif getattr(v.aval, "weak_type", False) and \
                        dt.startswith("float"):
                    out.append(LintFinding(
                        ep.name, "weak-float",
                        f"`{name}` produces weakly-typed {dt} inside a "
                        f"while body (Python float literal in the hot "
                        f"loop?)"))
        if name in _SCATTER_PRIMS:
            if eqn.params.get("mode") == GatherScatterMode.PROMISE_IN_BOUNDS:
                out.append(LintFinding(
                    ep.name, "scatter-mode",
                    f"`{name}` resolves to PROMISE_IN_BOUNDS (OOB "
                    f"writes are UB; use mode='drop'/'fill')"))

    _walk(jaxpr.jaxpr, False, visit)
    if ep.expect_while and counts["while"] == 0:
        out.append(LintFinding(ep.name, "structure",
                               "expected a while loop, found none"))
    if ep.cond_count is not None and counts["cond"] != ep.cond_count:
        sites = ", ".join(f"{k} ({v})"
                          for k, v in PROTOCOL_COND_SITES.items())
        out.append(LintFinding(
            ep.name, "cond-count",
            f"{counts['cond']} cond primitive(s), expected "
            f"{ep.cond_count} — registry sites: {sites}; a protocol "
            f"branch was folded, un-conded, or forked in Python"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LintReport:
    findings: list[LintFinding]
    entries: list[str]

    @property
    def ok(self) -> bool:
        return not self.findings

    def text(self) -> str:
        lines = [f"# jaxpr lint: {len(self.entries)} entry point(s), "
                 f"{len(self.findings)} finding(s)"]
        for name in self.entries:
            n = sum(1 for f in self.findings if f.entry == name)
            lines.append(f"{'FAIL' if n else 'ok  '} {name}"
                         + (f" ({n} finding(s))" if n else ""))
        lines += [str(f) for f in self.findings]
        lines.append("lint: " + ("PASS" if self.ok else "FAIL"))
        return "\n".join(lines)


def lint_entry(ep: EntryPoint) -> list[LintFinding]:
    """Twice-lower one entry point and run every rule. Never raises on a
    broken entry — lowering failures become findings (a knob concretized
    under trace is exactly the loud variant of the leak)."""
    # Build+lower each variant back to back: a wrapper that closes over
    # config-derived Python scalars binds them at build time, so variant
    # A must be lowered before variant B is built.
    try:
        stat_a, dyn_a = ep.build(0)
    except Exception as e:
        return [LintFinding(ep.name, "build",
                            f"builder raised {type(e).__name__}: {e}")]
    try:
        jx_a = _lower(ep, stat_a, dyn_a)
        txt_a = _text(jx_a)
    except Exception as e:
        return [LintFinding(ep.name, "concretized",
                            f"lowering raised {type(e).__name__}: {e} — "
                            f"a traced leaf was concretized")]
    out = _rule_findings(ep, jx_a)
    try:
        stat_b, dyn_b = ep.build(1)
    except Exception as e:
        out.append(LintFinding(ep.name, "build",
                               f"builder raised {type(e).__name__}: {e}"))
        return out
    if stat_b != stat_a:
        out.append(LintFinding(
            ep.name, "static-leak",
            f"value-like config change moved the static part: "
            f"{stat_a!r} != {stat_b!r}"))
        return out
    try:
        txt_b = _text(_lower(ep, stat_b, dyn_b))
    except Exception as e:
        out.append(LintFinding(ep.name, "concretized",
                               f"variant-B lowering raised "
                               f"{type(e).__name__}: {e}"))
        return out
    if txt_a != txt_b:
        who = _bisect_leak(ep, stat_a, dyn_a, dyn_b, txt_a)
        out.append(LintFinding(
            ep.name, "value-leak",
            "jaxpr differs across value-only config variants — traced "
            "knob constant-folded into the program; offending leaf "
            "path(s): " + (", ".join(who) if who else "(bisect found "
            "none: leak is in a non-leaf closure)")))
    return out


def run_lint(entries: Sequence[EntryPoint] | None = None) -> LintReport:
    eps = list(entries) if entries is not None else default_entry_points()
    findings: list[LintFinding] = []
    for ep in eps:
        findings.extend(lint_entry(ep))
    return LintReport(findings=findings, entries=[ep.name for ep in eps])


# ---------------------------------------------------------------------------
# negative control: a deliberately leaky entry point (CI selftest + tests)
# ---------------------------------------------------------------------------

def leaky_entry_point() -> EntryPoint:
    """An entry point with the exact bug the linter exists for: its
    builder Python-folds ``wait_timeout`` into a closure constant before
    the jit boundary. One compiled program per timeout value — the
    silent executable-cache fork. The linter must FAIL on it."""

    def build(v: int):
        cfg = _engine_cfg(v)
        wt = int(cfg.protocol.wait_timeout)     # BUG: folded eagerly

        def leaky(stat, dp, s0):
            dp = dp._replace(wait_timeout=jnp.asarray(wt, I32))
            return E._run_dyn.__wrapped__(stat, dp, s0)

        stat, dp = split_config(cfg)
        build.fn = leaky            # lowered via ep.fn at call time
        return (stat,), (dp, E.init_state_dyn(stat, dp))

    class _Proxy:
        # resolves to whichever closure build() last produced
        @property
        def __wrapped__(self):
            return build.fn

        def __call__(self, *a, **k):
            return build.fn(*a, **k)

    return EntryPoint("negative.leaky_run_dyn", _Proxy(), build,
                      cond_count=len(PROTOCOL_COND_SITES))
