"""Serving driver: batched decode with TXSQL-style group commit (§4.6.1).

Requests arriving concurrently are grouped into a decode batch. The batch
"leader" (first waiting request) fires a step when either the batch is
full OR — the dynamic-batch-size rule — no further requests are waiting;
a leader never stalls on an empty queue. Each fused step is the "group
commit": one model invocation serves the whole conflict group.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm_spec, init_params, prefill, decode_step
from repro.models.transformer import lm_init_cache
from repro.launch.mesh import make_host_mesh
from repro.obs import compile_log as _compile_log


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    order: int = -1                  # group order (hot_update_order analogue)


class GroupServer:
    """Fixed-slot continuous batching with dynamic group fire."""

    def __init__(self, cfg, params, batch_slots: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.caches = lm_init_cache(cfg, batch_slots, max_len)
        self.pos = jnp.zeros((), jnp.int32)
        self._order = 0
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, tokens=t, caches=c,
                                             pos=pos))
        _compile_log.register(self._decode)
        self.steps_fired = 0
        self.members_served = 0

    def submit(self, req: Request):
        req.order = self._order            # dependency-list order
        self._order += 1
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                self.active[i] = self.queue.popleft()

    def step(self) -> bool:
        """Fire one fused decode step (group commit). Returns progress."""
        self._admit()
        live = [r for r in self.active if r is not None]
        if not live:
            return False
        # group fire rule: full batch OR queue drained (dynamic batch)
        if len(live) < self.slots and self.queue:
            self._admit()
            live = [r for r in self.active if r is not None]
        toks = np.zeros((self.slots, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None:
                toks[i, 0] = (r.out[-1] if r.out else r.prompt[-1])
        nxt_logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches, self.pos)
        self.pos = self.pos + 1
        nxt = np.asarray(jnp.argmax(nxt_logits[:, -1], axis=-1))
        self.steps_fired += 1
        # commit in order: requests complete in their arrival order
        done = []
        for i, r in enumerate(self.active):
            if r is None:
                continue
            r.out.append(int(nxt[i]))
            self.members_served += 1
            if len(r.out) >= r.max_new:
                done.append((r.order, i))
        for _, i in sorted(done):          # ordered group commit
            self.active[i] = None
        return True


def serve_demo(arch: str = "qwen2-0.5b", n_requests: int = 12,
               batch_slots: int = 4):
    cfg = get_config(arch, smoke=True)
    params = init_params(lm_spec(cfg), jax.random.PRNGKey(0))
    srv = GroupServer(cfg, params, batch_slots=batch_slots)
    rng = np.random.default_rng(0)
    for rid in range(n_requests):
        srv.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 8,
                                               dtype=np.int32),
                           max_new=4 + rid % 5))
    t0 = time.perf_counter()
    while srv.step():
        pass
    dt = time.perf_counter() - t0
    print(f"[serve] {n_requests} requests, {srv.steps_fired} fused steps, "
          f"{srv.members_served} tokens, {dt*1e3:.0f}ms "
          f"(group efficiency {srv.members_served/max(srv.steps_fired,1):.2f}"
          f" tokens/step)")
    return srv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    serve_demo(args.arch, args.requests, args.slots)


if __name__ == "__main__":
    main()
