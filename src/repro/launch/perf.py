import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Perf-iteration harness (§Perf): lower a cell with config/recipe
overrides and report the three roofline terms, so each
hypothesis->change->measure cycle is one CLI invocation.

    python -m repro.launch.perf --arch qwen2-0.5b --shape train_4k \
        --set rules=train_dp
    python -m repro.launch.perf --arch deepseek-coder-33b \
        --shape decode_32k --set kv_dtype=float8_e4m3fn
"""

import argparse
import dataclasses
import json
import math

import jax
import jax.numpy as jnp

from repro.configs import get_config, SHAPES
from repro.distributed import ResolveReport, data_axes
from repro.distributed.sharding import _axis_size, set_activation_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch import dryrun as dr
from repro.launch.roofline import (collective_bytes, Roofline,
                                   model_flops_estimate,
                                   analytic_hbm_bytes)

CFG_KEYS = {"kv_dtype", "attn_chunk", "loss_chunk", "capacity_factor",
            "act_dtype", "remat", "moe_data_shards", "ssm_chunk", "window"}
RECIPE_KEYS = {"rules", "state_bits", "param_dtype"}


def run_variant(arch, shape_name, multi_pod, overrides, tag):
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.shape.values())
    cfg = get_config(arch)
    dp = _axis_size(mesh, data_axes(mesh))
    if cfg.n_experts:
        cfg = dataclasses.replace(
            cfg, moe_data_shards=math.gcd(dp, shape.global_batch))
    if shape.step == "train":
        cfg = dataclasses.replace(cfg, loss_chunk=512)
    dev_b = max(shape.global_batch // dp, 1)
    slab = dev_b * cfg.n_heads * shape.seq_len * 4
    chunk = 512
    while chunk > 64 and slab * chunk > (1 << 30):
        chunk //= 2
    cfg = dataclasses.replace(cfg, attn_chunk=chunk)

    recipe = dict(dr.TRAIN_RECIPE.get(arch, {}))
    cfg_over = {}
    for k, v in overrides.items():
        if k in RECIPE_KEYS:
            recipe[k] = (jnp.bfloat16 if v == "bfloat16" else
                         jnp.float32 if v == "float32" else
                         int(v) if k == "state_bits" else v)
        elif k in CFG_KEYS:
            field = ModelConfigField(k)
            cfg_over[k] = field(v)
        else:
            raise KeyError(k)
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)

    report = ResolveReport()
    set_activation_mesh(mesh)
    try:
        with mesh:
            lowered = dr._lower_for(cfg, shape, mesh, recipe, report)
            compiled = lowered.compile()
            flops_c, bytes_probe = dr.corrected_cost(cfg, shape, mesh,
                                                     recipe)
    finally:
        set_activation_mesh(None)
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text(),
                            default_trip=max(r for _, r in cfg.layout))
    n_params = cfg.param_count()
    if shape.step == "train":
        pdt = recipe.get("param_dtype", jnp.float32)
        bits = recipe.get("state_bits", 32)
        pbytes = n_params * jnp.dtype(pdt).itemsize
        obytes = n_params * 2 * {32: 4, 16: 2, 8: 1}[bits]
        shards = chips
    else:
        pbytes, obytes = n_params * 2, 0
        shards = mesh.shape.get("model", 1)
    roof = Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        flops=flops_c,
        bytes_accessed=analytic_hbm_bytes(cfg, shape, chips, pbytes,
                                          obytes, param_shards=shards),
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        model_flops=model_flops_estimate(cfg, shape))
    gb = 1 << 30
    print(f"[perf:{tag}] {arch} x {shape_name} x {roof.mesh}: "
          f"t_comp={roof.t_compute*1e3:.2f}ms "
          f"t_mem={roof.t_memory*1e3:.2f}ms "
          f"t_coll={roof.t_collective*1e3:.2f}ms "
          f"bottleneck={roof.bottleneck} "
          f"temps={(mem.temp_size_in_bytes or 0)/gb:.2f}GiB "
          f"mfu_bound={roof.mfu_bound:.3f} "
          f"coll={ {k: round(v/gb, 2) for k, v in coll.items() if v} }")
    return roof


def ModelConfigField(k):
    casts = {"attn_chunk": int, "loss_chunk": int, "moe_data_shards": int,
             "ssm_chunk": int, "window": int, "capacity_factor": float,
             "remat": lambda v: v in ("1", "true", "True")}
    return casts.get(k, str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="key=value override (cfg or recipe)")
    ap.add_argument("--tag", default="variant")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set)
    run_variant(args.arch, args.shape, args.multipod, overrides, args.tag)


if __name__ == "__main__":
    main()
