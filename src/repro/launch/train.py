"""Training driver: sharded train loop with checkpoint/restart, failure
detection, and straggler monitoring.

Usage (container-scale example; the production mesh is exercised by
``dryrun.py``):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Real-TPU XLA flags that pair with this driver (documented; harmless
elsewhere): ``--xla_tpu_enable_latency_hiding_scheduler=true`` (overlap
grad all-reduce with backward), ``--xla_tpu_spmd_rng_bit_generator_unsafe=
true`` (cheap per-device RNG).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm_spec, init_params, abstract_params
from repro.optim import adamw
from repro.data import DataConfig, init_state, make_batch
from repro.checkpoint import Checkpointer
from repro.distributed import (param_shardings, batch_shardings,
                               StragglerDetector, HeartbeatMonitor)
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.obs import compile_log as _compile_log


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: str | None, ckpt_every: int = 50, resume: bool = True,
          model_axis: int = 1, use_kernel: bool = False, log_every: int = 10):
    cfg = get_config(arch, smoke=smoke)
    opt_cfg = adamw.AdamWConfig(decay_steps=max(steps, 2))
    mesh = make_host_mesh(model_axis)
    specs = lm_spec(cfg)

    with jax.set_mesh(mesh):
        p_shard = param_shardings(specs, mesh, "train")
        init_fn = jax.jit(lambda k: init_params(lm_spec(cfg), k),
                          out_shardings=p_shard)
        _compile_log.register(init_fn)
        params = init_fn(jax.random.PRNGKey(0))
        opt_state = adamw.init(params)
        dstate = init_state()
        dc = DataConfig(seed=0)

        ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        start_step = 0
        if ckpt and resume and ckpt.latest_step() is not None:
            restored = ckpt.restore(None, (params, opt_state, dstate))
            params, opt_state, dstate = restored
            start_step = int(ckpt.latest_step())
            print(f"[train] resumed from step {start_step}")

        step_fn = jax.jit(make_train_step(cfg, opt_cfg, use_kernel),
                          donate_argnums=(0, 1))
        _compile_log.register(step_fn)
        detector = StragglerDetector()
        heart = HeartbeatMonitor()

        losses = []
        for step in range(start_step, steps):
            t0 = time.perf_counter()
            b, dstate = make_batch(dc, cfg, batch, seq, dstate)
            params, opt_state, metrics = step_fn(params, opt_state, b)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            detector.observe(0, dt)
            heart.beat(0)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={dt*1e3:.0f}ms")
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state, dstate))
        if ckpt:
            ckpt.save(steps, (params, opt_state, dstate), blocking=True)
            ckpt.wait()
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args()
    losses = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                   args.ckpt_dir, args.ckpt_every,
                   model_axis=args.model_axis, use_kernel=args.use_kernel)
    print(f"[train] done; loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
