"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
forced-host-device trick and for tests that expect 1 CPU device.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across API generations: jax >= 0.5 takes (and some
    sharding paths want) explicit Auto axis_types; 0.4.x has neither the
    kwarg nor ``jax.sharding.AxisType`` — where every mesh axis is Auto
    already. Regression caught by tests/test_sweep.py's forced-multi-
    device subprocess: lane sharding never engaged on 0.4.x because mesh
    construction itself raised."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_axis: int | None = None):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    m = model_axis or 1
    assert n % m == 0
    return _make_mesh((n // m, m), ("data", "model"))


def elastic_mesh_shape(n_devices: int, model_axis: int = 16):
    """Largest (pod, data, model) grid on surviving devices (fault path).

    Keeps the model axis intact (resharding TP state is the expensive
    direction); shrinks data parallelism to what survives.
    """
    while model_axis > 1 and n_devices % model_axis:
        model_axis //= 2
    data = max(n_devices // model_axis, 1)
    return (data, model_axis), ("data", "model")
