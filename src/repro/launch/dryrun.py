import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**abstract inputs).compile()`` must succeed
on the production meshes — (16,16) "data","model" and (2,16,16)
"pod","data","model" — for every assigned architecture x input shape.
``memory_analysis()`` proves the per-device fit; ``cost_analysis()`` +
HLO collective parsing feed the roofline table (EXPERIMENTS.md §Roofline).

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
    python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
Results are cached as JSON under experiments/dryrun/.
"""

import argparse
import dataclasses
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (get_config, input_specs, SHAPES, shape_grid,
                           ARCHS)
from repro.models import lm_spec, abstract_params
from repro.optim import adamw
from repro.distributed import (param_shardings, batch_shardings,
                               cache_shardings, scalar_sharding,
                               ResolveReport, data_axes)
from repro.distributed.sharding import _axis_size, set_activation_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_train_step, make_prefill_step,
                                make_serve_step)
from repro.launch.roofline import (collective_bytes, Roofline,
                                   model_flops_estimate,
                                   analytic_hbm_bytes)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# per-arch training recipe overrides: arctic-480b only fits a 256-chip pod
# with bf16 params + blockwise-int8 Adam moments (see DESIGN.md §5/§6).
TRAIN_RECIPE = {
    "arctic-480b": {"param_dtype": jnp.bfloat16, "state_bits": 8},
}

# per-arch config overrides applied to every shape of that arch
ARCH_OVERRIDES = {
    # chunk 128 halves the SSD intra-chunk working set (L and W decay
    # kernels scale with nc*Q^2 = S*Q)
    "mamba2-1.3b": {"ssm_chunk": 128},
}


def _quant_state_shardings(specs, mesh):
    """int8 moments mirror the parameter sharding exactly (q has the param
    shape); per-row scales drop the last axis."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import resolve_spec, RULES
    from repro.models.common import tree_map_specs

    def f(s):
        ps = resolve_spec(s.shape, s.axes, mesh, RULES["train"])
        entries = list(ps) + [None] * (len(s.shape) - len(list(ps)))
        return {"q": NamedSharding(mesh, P(*entries)),
                "s": NamedSharding(mesh, P(*entries[:-1], None))}
    return tree_map_specs(f, specs)


def _lower_for(cfg, shape, mesh, recipe, report=None):
    """Lower the step function a shape dictates, fully sharded."""
    specs = lm_spec(cfg)
    if shape.step == "train":
        pdt = recipe.get("param_dtype", jnp.float32)
        bits = recipe.get("state_bits", 32)
        rules = recipe.get("rules", "train")
        params = abstract_params(specs, pdt)
        opt = adamw.abstract_state(params, bits)
        p_shard = param_shardings(specs, mesh, rules, report)
        if bits in (32, 16):
            m_shard = p_shard
        else:
            m_shard = _quant_state_shardings(specs, mesh)
        o_shard = adamw.AdamWState(step=scalar_sharding(mesh),
                                   m=m_shard, v=m_shard)
        inputs = input_specs(cfg, shape)
        b_shard = batch_shardings(inputs["batch"], mesh,
                                  batch_dims={"positions3": 1})
        opt_cfg = adamw.AdamWConfig(state_bits=bits)
        fn = make_train_step(cfg, opt_cfg)
        jitted = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
        return jitted.lower(params, opt, inputs["batch"])
    if shape.step == "prefill":
        params = abstract_params(specs, jnp.bfloat16)
        p_shard = param_shardings(specs, mesh, "serve", report)
        inputs = input_specs(cfg, shape)
        i_shard = batch_shardings(inputs, mesh,
                                  batch_dims={"positions3": 1})
        fn = make_prefill_step(cfg)
        jitted = jax.jit(fn, in_shardings=(p_shard, i_shard))
        return jitted.lower(params, inputs)
    # decode
    params = abstract_params(specs, jnp.bfloat16)
    p_shard = param_shardings(specs, mesh, "serve", report)
    inputs = input_specs(cfg, shape)
    i_shard = dict(caches=cache_shardings(inputs["caches"], mesh),
                   pos=scalar_sharding(mesh))
    for k in ("tokens", "embeds", "positions3"):
        if k in inputs:
            i_shard[k] = batch_shardings(
                {k: inputs[k]}, mesh, batch_dims={"positions3": 1})[k]
    fn = make_serve_step(cfg)
    jitted = jax.jit(fn, in_shardings=(p_shard, i_shard),
                     donate_argnums=(1,))
    return jitted.lower(params, inputs)


def _with_reps(cfg, reps_list):
    layout = tuple((unit, r) for (unit, _), r in zip(cfg.layout, reps_list))
    return dataclasses.replace(cfg, layout=layout)


def corrected_cost(cfg, shape, mesh, recipe):
    """XLA cost_analysis counts while-loop (scan) bodies once; correct by
    linear extrapolation: cost(L) = a + sum_g b_g * reps_g, measured at
    all-reps=1 plus one extra compile per layer group."""
    n_g = len(cfg.layout)
    base_reps = [1] * n_g

    def cost_of(reps):
        # probes unroll layers AND disable the chunked (scan-based) attn/CE
        # paths so no flops hide inside uncounted loop bodies. Probes are
        # only lowered+compiled, never run, so their memory is irrelevant.
        probe_cfg = dataclasses.replace(
            _with_reps(cfg, reps), unroll_layers=True, loss_chunk=0,
            attn_chunk=0)
        low = _lower_for(probe_cfg, shape, mesh, recipe)
        c = low.compile().cost_analysis()
        return (float(c.get("flops", 0.0)),
                float(c.get("bytes accessed", 0.0)))

    f0, b0 = cost_of(base_reps)
    flops, byts = f0, b0
    for g, (_, reps_g) in enumerate(cfg.layout):
        if reps_g == 1:
            continue
        reps = list(base_reps)
        reps[g] = 2
        f1, b1 = cost_of(reps)
        flops += (f1 - f0) * (reps_g - 1)
        byts += (b1 - b0) * (reps_g - 1)
    return flops, byts


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True):
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.shape.values())
    cfg = get_config(arch)
    if arch in ARCH_OVERRIDES:
        cfg = dataclasses.replace(cfg, **ARCH_OVERRIDES[arch])
    dp = _axis_size(mesh, data_axes(mesh))
    if cfg.n_experts:
        ds = math.gcd(dp, shape.global_batch)
        cfg = dataclasses.replace(cfg, moe_data_shards=ds)
    if shape.step == "train":
        cfg = dataclasses.replace(cfg, loss_chunk=512)
    if shape.step in ("train", "prefill") and cfg.kinds() & {
            ("global", "dense"), ("global", "moe"),
            ("global", "moe+dense")} or True:
        # query-block chunking keeps per-device score slabs ~<=1 GiB
        dev_b = max(shape.global_batch // dp, 1)
        slab = dev_b * cfg.n_heads * shape.seq_len * 4
        chunk = 512
        while chunk > 64 and slab * chunk > (1 << 30):
            chunk //= 2
        cfg = dataclasses.replace(cfg, attn_chunk=chunk)

    recipe = TRAIN_RECIPE.get(arch, {})
    report = ResolveReport()

    set_activation_mesh(mesh)
    try:
        with mesh:
            lowered = _lower_for(cfg, shape, mesh, recipe, report)
            t0 = time.time()
            compiled = lowered.compile()
            compile_s = time.time() - t0
            flops_c, bytes_c = corrected_cost(cfg, shape, mesh, recipe)
    finally:
        set_activation_mesh(None)

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    reps = max(r for _, r in cfg.layout)
    coll = collective_bytes(hlo, default_trip=reps)

    n_params = cfg.param_count()
    if shape.step == "train":
        pdt = recipe.get("param_dtype", jnp.float32)
        bits = recipe.get("state_bits", 32)
        pbytes = n_params * jnp.dtype(pdt).itemsize
        obytes = n_params * 2 * {32: 4, 16: 2, 8: 1}[bits]
        shards = chips                      # FSDP: fully sharded
    else:
        pbytes = n_params * 2
        obytes = 0
        shards = mesh.shape.get("model", 1)  # serve: TP only
    hbm_bytes = analytic_hbm_bytes(cfg, shape, chips, pbytes, obytes,
                                   param_shards=shards)

    roof = Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        chips=chips,
        flops=flops_c,
        bytes_accessed=hbm_bytes,
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops_estimate(cfg, shape),
    )
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": roof.mesh, "chips": chips,
        "compile_s": compile_s,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                  None),
        },
        "sharding_fallbacks": len(report.fallbacks),
        "hlo_bytes_probe": bytes_c,
        "roofline": roof.row(),
    }
    gb = 1 << 30
    arg = (result["memory"]["argument_bytes"] or 0) / gb
    tmp = (result["memory"]["temp_bytes"] or 0) / gb
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {roof.mesh}: "
              f"compile={compile_s:.1f}s args={arg:.2f}GiB "
              f"temps={tmp:.2f}GiB bottleneck={roof.bottleneck} "
              f"t=({roof.t_compute*1e3:.2f},{roof.t_memory*1e3:.2f},"
              f"{roof.t_collective*1e3:.2f})ms "
              f"useful={roof.useful_ratio:.2f}")
    return result


def cell_path(arch, shape_name, multi_pod):
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh}.json")


def run_cell(arch, shape_name, multi_pod, force=False):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = cell_path(arch, shape_name, multi_pod)
    if os.path.exists(path) and not force:
        with open(path) as f:
            r = json.load(f)
        if "error" not in r:
            print(f"[dryrun] cached: {os.path.basename(path)}")
            return r
    try:
        result = lower_cell(arch, shape_name, multi_pod)
    except Exception as e:
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
        print(f"[dryrun] FAIL {arch} x {shape_name}: {result['error']}")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        ok = fail = 0
        for arch in ARCHS:
            for shape in shape_grid(arch):
                for mp in (False, True):
                    r = run_cell(arch, shape.name, mp, args.force)
                    if "error" in r:
                        fail += 1
                    else:
                        ok += 1
        print(f"[dryrun] {ok} cells OK, {fail} failed")
        raise SystemExit(1 if fail else 0)

    assert args.arch and args.shape
    r = run_cell(args.arch, args.shape, args.multipod, args.force)
    raise SystemExit(1 if "error" in r else 0)


if __name__ == "__main__":
    main()
