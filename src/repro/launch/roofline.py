"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e-like, per chip):
    197 TFLOP/s bf16  |  819 GB/s HBM  |  ~50 GB/s/link ICI (x3 links)

Terms (seconds, per step, per chip):
    compute    = HLO_FLOPs / (chips * PEAK)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

``cost_analysis`` reports whole-program FLOPs/bytes (already partitioned —
the SPMD module is per-device, so no division by chips is applied to those
numbers; they ARE per-device). Collective bytes are parsed from the
optimized HLO text: operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, with while-loop bodies
(scanned layer groups) multiplied by their trip count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (one direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'f32[128,1024]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _trip_count(body_text: str) -> Optional[int]:
    """Best-effort trip count from a while-loop condition constant."""
    m = re.search(r"compare\([^)]*\)[^\n]*direction=LT", body_text)
    return None


def collective_bytes(hlo_text: str, default_trip: int = 1) -> Dict[str, int]:
    """Sum collective operand bytes from optimized HLO text.

    Instructions inside computations whose name suggests a while body are
    multiplied by ``default_trip`` (callers pass the scanned layer count —
    the dominant loop in every model here).
    """
    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    current_mult = 1
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers look like: %name (args) -> type {  /  ENTRY..
        if (stripped.startswith("%") or stripped.startswith("ENTRY")) \
                and stripped.endswith("{"):
            lname = stripped.lower()
            current_mult = default_trip if (
                "while" in lname or "body" in lname
                or "scan" in lname) else 1
            continue
        for c in _COLLECTIVES:
            if f" {c}(" in stripped or f"= {c}" in stripped \
                    or stripped.startswith(c) or f"{c}-start" in stripped:
                # output shape is on the lhs: %x = TYPE collective(...)
                lhs = stripped.split("=", 1)
                shape_part = lhs[1] if len(lhs) == 2 else stripped
                b = _shape_bytes(shape_part.split("(", 1)[0])
                if b == 0:
                    b = _shape_bytes(shape_part)
                per_op[c] += b * current_mult
                break
    return per_op


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                 # per-device HLO flops
    bytes_accessed: float        # per-device HLO bytes
    coll_bytes: float            # per-device collective bytes
    coll_breakdown: Dict[str, int]
    model_flops: float           # 6*N*D useful flops (global)
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs) — remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on achievable MFU given the dominant term."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.chips / self.peak_flops) \
            / self.t_bound

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops_per_chip": self.flops,
            "hlo_bytes_per_chip": self.bytes_accessed,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu_bound,
        }


def analytic_hbm_bytes(cfg, shape, chips: int, param_bytes: int,
                       opt_bytes: int = 0,
                       param_shards: int | None = None) -> float:
    """Per-chip HBM traffic of the schedule we actually lower (bytes/step).

    XLA's ``cost_analysis()['bytes accessed']`` cannot express our chunked
    attention/CE (loop bodies count once) and the dense probes overcount
    score traffic by the S/blk factor flash-style execution avoids, so the
    memory term is derived from the schedule itself:

    train:  3x param reads (fwd + remat recompute + bwd) + grad write/read
            + optimizer state read/write + param write
            + activation traffic (residual stream + block io, ~10 tensor
              passes per layer with remat)
            + flash KV re-reads (K,V once per query block)
            + chunked-CE logits write/read (fwd+bwd, chunk-local)
    prefill: 1x param read + activation writes + cache write
    decode:  1x param read + full cache read + one-position cache write
    """
    act = 2                                   # bf16 activations
    d = cfg.d_model
    L = cfg.n_layers
    tokens = shape.global_batch * shape.seq_len / chips
    # params fully sharded when training (FSDP); TP-only when serving
    shards = param_shards or chips
    pb = param_bytes / shards
    ob = opt_bytes / shards

    def attn_layers():
        return sum(reps * sum(1 for k, _ in unit
                              if k in ("global", "local", "mla"))
                   for unit, reps in cfg.layout)

    if shape.step == "train":
        traffic = 3 * pb + 2 * pb + 2 * ob + pb
        traffic += tokens * d * act * L * 10
        # flash KV re-reads: K/V row per query block
        nb = max(shape.seq_len // max(cfg.attn_chunk or shape.seq_len, 1),
                 1)
        kv_row = (cfg.kv_lora_rank + cfg.qk_rope_dim) if cfg.kv_lora_rank \
            else 2 * cfg.n_kv_heads * cfg.hd
        traffic += (shape.global_batch / chips) * shape.seq_len * kv_row \
            * act * attn_layers() * nb * 2          # fwd + bwd repass
        # chunked CE: logits written+read fwd, recomputed in bwd
        traffic += tokens * cfg.padded_vocab * act * 3
        return traffic
    if shape.step == "prefill":
        traffic = pb + tokens * d * act * L * 4
        traffic += tokens * cfg.padded_vocab * act / shape.seq_len  # last
        return traffic
    # decode: params once + cache read
    import jax as _jax
    import jax.numpy as _jnp
    import numpy as _np
    from repro.models.transformer import lm_cache_shapes
    cache = lm_cache_shapes(cfg, shape.global_batch, shape.seq_len,
                            _jnp.dtype(cfg.kv_dtype))
    cache_bytes = sum(int(_np.prod(leaf.shape)) * leaf.dtype.itemsize
                      for leaf in _jax.tree.leaves(cache))
    return pb + cache_bytes / chips * 1.02    # read all + write 1 position


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D for training; 2*N_active*D for a forward; decode counts one
    token per sequence."""
    n_active = cfg.active_param_count()
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    tokens = shape.global_batch
    flops = 2.0 * n_active * tokens
    # attention reads: 2 * cache_len * d per kv head pair ~ folded into
    # bytes, not FLOPs-dominant; keep parameter term.
    return flops
