"""Step functions lowered by the dry-run and driven by train.py/serve.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import loss_fn, decode_step, prefill
from repro.optim import adamw


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, use_kernel=False):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, use_kernel=use_kernel),
            has_aux=True)(params)
        new_params, new_opt, om = adamw.apply(opt_cfg, grads, opt_state,
                                              params)
        return new_params, new_opt, {"loss": loss, **metrics, **om}
    return train_step


def make_prefill_step(cfg, use_kernel=False):
    def prefill_step(params, inputs):
        logits, caches = prefill(params, cfg,
                                 tokens=inputs.get("tokens"),
                                 embeds=inputs.get("embeds"),
                                 positions3=inputs.get("positions3"),
                                 use_kernel=use_kernel)
        return logits, caches
    return prefill_step


def make_serve_step(cfg):
    def serve_step(params, inputs):
        logits, caches = decode_step(
            params, cfg,
            tokens=inputs.get("tokens"),
            embeds=inputs.get("embeds"),
            caches=inputs["caches"],
            pos=inputs["pos"],
            positions3=inputs.get("positions3"))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, caches
    return serve_step
