"""Recompute roofline terms in cached dry-run JSONs after analytic-model
changes (FLOPs and collective bytes come from the stored compile results;
only the memory term and derived fields are re-derived)."""
import dataclasses
import glob
import json
import math
import os
import sys

import jax.numpy as jnp

from repro.configs import get_config, SHAPES
from repro.launch.roofline import (Roofline, analytic_hbm_bytes,
                                   model_flops_estimate)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
TRAIN_RECIPE = {"arctic-480b": {"param_dtype": jnp.bfloat16,
                                "state_bits": 8}}
ARCH_OVERRIDES = {"mamba2-1.3b": {"ssm_chunk": 128}}


def main():
    for path in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        r = json.load(open(path))
        if "error" in r:
            continue
        arch, shape_name = r["arch"], r["shape"]
        multi = r["mesh"] == "2x16x16"
        chips = r["chips"]
        shape = SHAPES[shape_name]
        cfg = get_config(arch)
        if arch in ARCH_OVERRIDES:
            cfg = dataclasses.replace(cfg, **ARCH_OVERRIDES[arch])
        dp = (2 * 16 if multi else 16)
        if shape.step == "train":
            cfg = dataclasses.replace(cfg, loss_chunk=512)
        dev_b = max(shape.global_batch // dp, 1)
        slab = dev_b * cfg.n_heads * shape.seq_len * 4
        chunk = 512
        while chunk > 64 and slab * chunk > (1 << 30):
            chunk //= 2
        cfg = dataclasses.replace(cfg, attn_chunk=chunk)
        n = cfg.param_count()
        recipe = TRAIN_RECIPE.get(arch, {})
        if shape.step == "train":
            pdt = recipe.get("param_dtype", jnp.float32)
            bits = recipe.get("state_bits", 32)
            pbytes = n * jnp.dtype(pdt).itemsize
            obytes = n * 2 * {32: 4, 16: 2, 8: 1}[bits]
            shards = chips
        else:
            pbytes, obytes, shards = n * 2, 0, 16
        roof = Roofline(
            arch=arch, shape=shape_name, mesh=r["mesh"], chips=chips,
            flops=r["roofline"]["hlo_flops_per_chip"],
            bytes_accessed=analytic_hbm_bytes(
                cfg, shape, chips, pbytes, obytes, param_shards=shards),
            coll_bytes=r["roofline"]["coll_bytes_per_chip"],
            coll_breakdown=r["roofline"].get("coll_breakdown", {}),
            model_flops=model_flops_estimate(cfg, shape))
        r["roofline"] = roof.row()
        with open(path, "w") as f:
            json.dump(r, f, indent=1, default=str)
    print("rederived", len(glob.glob(os.path.join(OUT_DIR, "*.json"))))


if __name__ == "__main__":
    main()
