"""Blocker blame attribution from TraceBuf events (DESIGN.md §14).

Answers the question the per-thread wait profile cannot: not just *where*
threads waited but *who made them wait*. Built entirely on the host from
the on-device event buffer (``repro.obs.trace``):

* **Holder intervals** — an ``EV_GRANT`` opens a hold of (thread, row);
  the hold closes at the thread's next ``EV_COMMIT``/``EV_ABORT``
  terminator (strict 2PL releases everything there) or at an
  ``EV_RELEASE`` on that row (brook per-op early release). A thread's
  transaction *attempt* is identified by counting its terminators, so
  blame lands on a specific attempt, not just a thread slot.
* **Blame matrix** — each wait span (``EV_WAIT_ENTER`` paired with the
  ``EV_GRANT``/``EV_TIMEOUT``/``EV_VICTIM`` that resolved it, the same
  pairing as ``export._wait_spans``) is overlapped with the holder
  intervals on its row; the overlap ticks are blamed on the holding
  attempt. Under group locking several members hold a hot row
  concurrently, so the matrix can over-count a span (every concurrent
  holder is blamed in full for the time it contributed to blocking);
  ``per_record`` counts each span once and therefore matches the wait
  profile's queued ticks exactly.
* **Critical path** — the longest blocking chain: a waiter's dominant
  blocker was often itself waiting (on another row) for most of the
  hold; following dominant blockers hop by hop yields the paper's
  convoy picture with per-hop durations. Cycles (deadlocks before
  victimization) are cut at the first repeated thread.

Dropped events make every number a lower bound — reports carry the same
warning header as the wait profile.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from .export import _as_events, _wait_spans
from .trace import EVENTS, EV_ABORT, EV_COMMIT, EV_GRANT, EV_RELEASE


def _holder_intervals(ev: dict, end: int | None = None) -> dict:
    """row -> time-sorted list of (t0, t1, tid, attempt) hold intervals.

    Holds still open at the end of the capture window close at ``end``
    (default: last recorded tick), mirroring ``_wait_spans``.
    """
    attempts: dict = defaultdict(int)
    open_by_tid: dict = defaultdict(dict)        # tid -> {row: t0}
    out: dict = defaultdict(list)
    n = ev["n"]
    tail = int(ev["ts"][n - 1]) if n else 0
    close_t = tail if end is None else int(end)
    for i in range(n):
        t, tid, row, e = (int(ev["ts"][i]), int(ev["tid"][i]),
                          int(ev["row"][i]), int(ev["ev"][i]))
        if e == EV_GRANT:
            open_by_tid[tid][row] = t
        elif e == EV_RELEASE:
            t0 = open_by_tid[tid].pop(row, None)
            if t0 is not None:
                out[row].append((t0, t, tid, attempts[tid]))
        elif e in (EV_COMMIT, EV_ABORT):
            for r0, t0 in open_by_tid.pop(tid, {}).items():
                out[r0].append((t0, t, tid, attempts[tid]))
            attempts[tid] += 1
    for tid, rows in open_by_tid.items():
        for r0, t0 in rows.items():
            out[r0].append((t0, max(close_t, t0), tid, attempts[tid]))
    for row in out:
        out[row].sort()
    return dict(out)


@dataclasses.dataclass
class BlameResult:
    """Blame attribution over one trace capture.

    ``matrix`` maps a blocking attempt ``(tid, attempt)`` to
    ``{row: blame_ticks}``; ``per_txn`` and ``per_record`` are its two
    marginals, except ``per_record`` counts every wait span once (no
    concurrent-holder over-count) so it equals the wait profile's queued
    ticks per row. ``unattributed`` is wait time with no recorded holder
    overlapping (holder's grant predates the capture, or events were
    dropped).
    """
    matrix: dict
    per_txn: dict
    per_record: dict
    unattributed: dict
    total_wait: int
    n_spans: int
    dropped: int

    def top_blockers(self, k: int = 10) -> list:
        """[(tid, attempt), blame_ticks] heaviest blocking attempts."""
        return sorted(self.per_txn.items(), key=lambda kv: -kv[1])[:k]

    def top_records(self, k: int = 10) -> list:
        return sorted(self.per_record.items(), key=lambda kv: -kv[1])[:k]


def blame_matrix(trace_or_events, end: int | None = None) -> BlameResult:
    """Attribute every wait span's ticks to the attempts holding its row."""
    ev = _as_events(trace_or_events)
    holders = _holder_intervals(ev, end=end)
    matrix: dict = defaultdict(lambda: defaultdict(int))
    per_txn: dict = defaultdict(int)
    per_record: dict = defaultdict(int)
    unattributed: dict = defaultdict(int)
    total = n_spans = 0
    for tid, row, t0, t1, _e in _wait_spans(ev, end=end):
        n_spans += 1
        total += t1 - t0
        per_record[row] += t1 - t0
        covered = 0
        for h0, h1, htid, hatt in holders.get(row, ()):
            if h0 >= t1:
                break
            if htid == tid:
                continue
            ov = min(t1, h1) - max(t0, h0)
            if ov > 0:
                matrix[(htid, hatt)][row] += ov
                per_txn[(htid, hatt)] += ov
                covered = max(covered, min(t1, h1))
        # conservative uncovered estimate: ticks past the furthest
        # overlapping holder end (0 when fully covered)
        reach = max(covered, t0)
        if reach < t1:
            unattributed[row] += t1 - reach
    return BlameResult(
        matrix={k: dict(v) for k, v in matrix.items()},
        per_txn=dict(per_txn), per_record=dict(per_record),
        unattributed=dict(unattributed), total_wait=total,
        n_spans=n_spans, dropped=int(ev["dropped"]))


def critical_path(trace_or_events, end: int | None = None,
                  max_hops: int = 64) -> list:
    """The longest blocking chain, as hop dicts (waiter -> blocker -> ...).

    Each wait span's *dominant* blocker is the attempt with the largest
    overlap on its row; if that blocker has a wait span of its own
    overlapping the same window, the chain continues there. The returned
    list starts at the chain head (the longest total blocked time) with
    per-hop ``{"tid", "row", "t0", "t1", "dur", "blocker"}``; cycles
    (deadlocks before victimization) are cut at the first repeat.
    """
    ev = _as_events(trace_or_events)
    holders = _holder_intervals(ev, end=end)
    spans = list(_wait_spans(ev, end=end))
    by_tid: dict = defaultdict(list)
    for i, (tid, _row, t0, t1, _e) in enumerate(spans):
        by_tid[tid].append(i)

    def dominant_blocker(i):
        tid, row, t0, t1, _e = spans[i]
        best, best_ov = None, 0
        for h0, h1, htid, hatt in holders.get(row, ()):
            if h0 >= t1:
                break
            if htid == tid:
                continue
            ov = min(t1, h1) - max(t0, h0)
            if ov > best_ov:
                best, best_ov = (htid, hatt), ov
        return best

    def next_span(i, blocker_tid):
        """The blocker's own wait span with max overlap of span i."""
        _tid, _row, t0, t1, _e = spans[i]
        best, best_ov = None, 0
        for j in by_tid.get(blocker_tid, ()):
            jt0, jt1 = spans[j][2], spans[j][3]
            ov = min(t1, jt1) - max(t0, jt0)
            if ov > best_ov:
                best, best_ov = j, ov
        return best

    memo: dict = {}

    def chain(i, seen):
        if i in memo:
            return memo[i]
        tid, row, t0, t1, _e = spans[i]
        hop = {"tid": tid, "row": row, "t0": t0, "t1": t1, "dur": t1 - t0,
               "blocker": None}
        rest: list = []
        b = dominant_blocker(i)
        if b is not None:
            hop["blocker"] = b
            j = next_span(i, b[0])
            if (j is not None and spans[j][0] not in seen
                    and len(seen) < max_hops):
                rest = chain(j, seen | {spans[j][0]})
        out = [hop] + rest
        memo[i] = out
        return out

    best: list = []
    best_dur = -1
    for i in range(len(spans)):
        c = chain(i, {spans[i][0]})
        dur = sum(h["dur"] for h in c)
        if dur > best_dur:
            best, best_dur = c, dur
    return best


def blame_table(trace_or_events, top_k: int = 10,
                end: int | None = None) -> str:
    """Per-record blame table (text), the companion of ``wait_profile``.

    One line per contended record: its queued ticks (identical to the
    wait profile's number), the share attributed to recorded holders,
    and the single heaviest blocking attempt with its blame share.
    """
    b = blame_matrix(trace_or_events, end=end)
    lines = []
    if b.dropped:
        lines.append(f"# WARNING: {b.dropped} events dropped — blame is "
                     f"a lower bound")
    lines.append(f"# blame table: {len(b.per_record)} contended rows, "
                 f"{b.n_spans} wait spans, {b.total_wait} queued ticks")
    lines.append("row,queued_ticks,attributed_frac,top_blocker,"
                 "top_blocker_ticks")
    # row -> heaviest (attempt, ticks)
    heaviest: dict = {}
    for txn, rows in b.matrix.items():
        for row, ticks in rows.items():
            if ticks > heaviest.get(row, (None, 0))[1]:
                heaviest[row] = (txn, ticks)
    for row, ticks in b.top_records(top_k):
        attr = 1.0 - b.unattributed.get(row, 0) / ticks if ticks else 0.0
        txn, bt = heaviest.get(row, (None, 0))
        who = f"t{txn[0]}#{txn[1]}" if txn else "-"
        lines.append(f"{row},{ticks},{attr:.2f},{who},{bt}")
    chain = critical_path(trace_or_events, end=end)
    if chain:
        hops = " -> ".join(
            f"t{h['tid']}@r{h['row']}({h['dur']}t)" for h in chain[:8])
        lines.append(f"# critical path ({len(chain)} hops, "
                     f"{sum(h['dur'] for h in chain)} blocked ticks): "
                     + hops)
    return "\n".join(lines)
