"""TickBreakdown helpers: conservation checks and fraction tables.

The engine charges every thread-tick of every iteration to exactly one
``(branch, bin)`` cell of ``Globals.tb`` (see ``engine._TB_PHASE_BIN`` and
DESIGN.md §11), so for any run or segment observed at the padded thread
count T::

    sum(tb) == T * elapsed_ticks

holds *exactly* (both sides are i32 sums of the same per-iteration
``T * dt`` contributions, so the identity survives wraparound mod 2^32 —
irrelevant at test scales, exact at any scale).
"""
from __future__ import annotations

import numpy as np

from repro.core.lock.engine import TB_NAMES


def _tb_of(obj):
    """Accept a SimState, a Globals, or a raw (branches, N_TB) array."""
    g = getattr(obj, "g", obj)
    tb = getattr(g, "tb", g)
    return np.asarray(tb, dtype=np.int64)


def tick_sum(obj) -> int:
    """Total attributed thread-ticks of a state/Globals/tb array."""
    return int(_tb_of(obj).sum())


def check_conservation(obj, n_threads: int, elapsed: int | None = None):
    """Assert sum(breakdown) == n_threads * elapsed_ticks.

    ``n_threads`` must be the PADDED thread count (padded HALT threads
    accrue idle ticks — they are real simulated thread-time). ``elapsed``
    defaults to ``g.now`` (whole run); pass a window length for segments.
    Returns the common value so callers can report it.
    """
    g = getattr(obj, "g", obj)
    if elapsed is None:
        elapsed = int(g.now)
    got = tick_sum(obj)
    want = int(n_threads) * int(elapsed)
    if got != want:
        raise AssertionError(
            f"tick-conservation violated: sum(breakdown)={got} != "
            f"T*elapsed={n_threads}*{elapsed}={want} (diff {got - want})")
    return got


def fractions(bd: dict) -> dict:
    """{bin: ticks} -> {bin: fraction of total}; empty-safe."""
    total = sum(bd.values())
    if total <= 0:
        return {k: 0.0 for k in bd}
    return {k: v / total for k, v in bd.items()}


def breakdown_row(bd: dict, prec: int = 3) -> str:
    """One 'k=v;k=v' fragment of bin fractions for benchmark rows."""
    fr = fractions(bd)
    return ";".join(f"{k}={fr.get(k, 0.0):.{prec}f}" for k in TB_NAMES)
