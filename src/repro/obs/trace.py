"""On-device lock-event tracing inside the engine's ``lax.while_loop``.

The engine's step already *computes* every interesting transition mask
(grants, waits, timeouts, deadlock victims, early releases, group joins,
commits) — it just throws them away. :func:`_make_step_events` surfaces
them as :class:`repro.core.lock.engine.StepEvents`, and this module
appends them to a fixed-allocation device buffer each iteration:

* **Capacity is data, not shape** (DESIGN.md §11): the buffer *allocation*
  is a shape (part of the compile key, like T/L/R), but the usable
  *capacity* and the master ``on`` switch are traced i32/bool leaves of
  :class:`TraceBuf`. One compiled program serves every capacity up to the
  allocation and both trace settings — ``trace_on=False`` runs the
  identical arithmetic on the identical state leaves and writes nothing,
  so it is bit-exact with the untraced engine (asserted in
  tests/test_obs.py) and adds nothing to the compile key.
* **Full buffer drops, never wraps**: once ``n`` reaches ``cap`` further
  events bump ``dropped`` and leave stored entries untouched. A prefix of
  the truth beats a corrupted ring for debugging, and the drop counter
  makes truncation loud.
* Events are appended in simulated-time order by construction:
  start-of-interval events (``t_pre``) precede end-of-interval events
  (``t_post``) within an iteration, and ``t_post`` of iteration k equals
  ``t_pre`` of iteration k+1. Exports never need to sort.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.lock import engine
from repro.core.lock.costs import CostModel, protocol_params
from repro.core.lock.engine import (DynParams, EngineConfig, I32, INF, NOTK,
                                    SegSnapshot, SimState, StaticShape,
                                    StepEvents, split_config, init_state_dyn)
from repro.core.lock.workload import WorkloadSpec

# event ids — index into EVENTS; stable across PRs (traces are artifacts),
# so new events only ever APPEND ("abort" = rollback completed, any cause —
# the attempt terminator the isolation certifier partitions on)
EVENTS = ("grant", "wait_enter", "timeout", "deadlock_victim",
          "early_release", "group_join", "commit", "abort")
(EV_GRANT, EV_WAIT_ENTER, EV_TIMEOUT, EV_VICTIM, EV_RELEASE, EV_GROUP_JOIN,
 EV_COMMIT, EV_ABORT) = range(len(EVENTS))


class TraceBuf(NamedTuple):
    """Fixed-allocation event buffer; all scalars traced (see module doc)."""
    ts: jnp.ndarray       # (A,) i32 tick of the event
    tid: jnp.ndarray      # (A,) i32 thread id
    row: jnp.ndarray      # (A,) i32 row id (NOTK for thread-level events)
    ev: jnp.ndarray       # (A,) i32 event id (index into EVENTS)
    n: jnp.ndarray        # ()   i32 events stored
    dropped: jnp.ndarray  # ()   i32 events dropped at capacity
    cap: jnp.ndarray      # ()   i32 usable capacity (traced, <= A)
    on: jnp.ndarray      # ()   bool master switch (traced)


def make_trace(cap: int = 4096, alloc: int | None = None,
               on: bool = True) -> TraceBuf:
    """Fresh buffer. ``alloc`` (static, defaults to ``cap``) is the compile
    key; ``cap``/``on`` are traced — vary them freely on one executable."""
    A = int(alloc if alloc is not None else cap)
    return TraceBuf(
        ts=jnp.full((A,), NOTK), tid=jnp.full((A,), NOTK),
        row=jnp.full((A,), NOTK), ev=jnp.full((A,), NOTK),
        n=jnp.asarray(0, I32), dropped=jnp.asarray(0, I32),
        cap=jnp.asarray(min(int(cap), A), I32), on=jnp.asarray(on, bool))


def _record(tbuf: TraceBuf, se: StepEvents) -> TraceBuf:
    """Append one iteration's events (device, inside the while_loop).

    Blocks are laid out t_pre-first so the buffer stays time-ordered; a
    compaction cumsum packs the fired events densely, positions past
    ``cap`` fall off via ``mode="drop"`` scatters and count as dropped.
    With ``on=False`` every mask is false and the whole call is the
    identity on ``tbuf`` — the zero-cost-off argument in one line.
    """
    T = se.grant.shape[0]
    tids = jnp.arange(T, dtype=I32)
    no_row = jnp.full((T,), NOTK)
    blocks = (
        (se.timeout, se.t_pre, se.row_cur, EV_TIMEOUT),
        (se.victim, se.t_pre, se.row_cur, EV_VICTIM),
        (se.grant, se.t_pre, se.row_cur, EV_GRANT),
        (se.group_join, se.t_pre, se.row_cur, EV_GROUP_JOIN),
        (se.release, se.t_post, se.row_cur, EV_RELEASE),
        (se.commit, se.t_post, no_row, EV_COMMIT),
        (se.abort, se.t_post, no_row, EV_ABORT),
        (se.wait_enter, se.t_post, se.row_begin, EV_WAIT_ENTER),
    )
    m = jnp.concatenate([b[0] & tbuf.on for b in blocks])
    ts = jnp.concatenate([jnp.broadcast_to(b[1], (T,)) for b in blocks])
    row = jnp.concatenate([b[2] for b in blocks])
    evid = jnp.concatenate([jnp.full((T,), b[3], I32) for b in blocks])
    tid = jnp.concatenate([tids] * len(blocks))

    pos = tbuf.n + jnp.cumsum(m.astype(I32)) - 1     # dense append position
    ok = m & (pos < tbuf.cap)
    A = tbuf.ts.shape[0]
    slot = jnp.where(ok, pos, A)                      # OOB -> dropped
    total = m.sum().astype(I32)
    stored = ok.sum().astype(I32)
    return tbuf._replace(
        ts=tbuf.ts.at[slot].set(ts, mode="drop"),
        tid=tbuf.tid.at[slot].set(tid, mode="drop"),
        row=tbuf.row.at[slot].set(row, mode="drop"),
        ev=tbuf.ev.at[slot].set(evid, mode="drop"),
        n=tbuf.n + stored,
        dropped=tbuf.dropped + (total - stored))


@functools.partial(jax.jit, static_argnums=0)
def _run_traced(stat: StaticShape, dp: DynParams, s0: SimState,
                tb0: TraceBuf,
                until) -> tuple[SimState, TraceBuf, SegSnapshot]:
    """Traced twin of ``engine._run_dyn``/``_run_seg_dyn``: same step, same
    cond, with the TraceBuf riding in the loop carry. ``until`` is traced
    (pass INF for whole-run; a finite boundary pauses at the segment edge
    exactly like ``run_segment``). One executable per (shape, alloc)."""
    step_ev = engine._make_step_events(stat, dp, until=until)
    cond = engine._make_cond(dp, until=until)

    def body(carry):
        s, tb = carry
        s2, ev = step_ev(s)
        return s2, _record(tb, ev)

    s, tb = lax.while_loop(lambda c: cond(c[0]), body, (s0, tb0))
    return s, tb, engine._snapshot(stat, dp, s)


def run_traced(stat: StaticShape, dp: DynParams, state: SimState,
               tbuf: TraceBuf,
               until=None) -> tuple[SimState, TraceBuf, SegSnapshot]:
    """Advance ``state`` with event tracing; resumable like run_segment.

    With ``tbuf.on`` false the returned state is bit-exact with the
    untraced entry points (same step sequence, same arithmetic)."""
    u = INF if until is None else jnp.asarray(until, I32)
    return _run_traced(stat, dp, state, tbuf, u)


def simulate_traced(protocol: str, workload: WorkloadSpec, n_threads: int,
                    costs: CostModel | None = None,
                    horizon: int = 2_000_000, p_abort: float = 0.0,
                    drain: bool = False, seed: int = 0, cap: int = 4096,
                    alloc: int | None = None, trace_on: bool = True,
                    attrib: bool = False,
                    **proto_over) -> tuple[SimState, TraceBuf]:
    """Traced twin of :func:`repro.core.lock.simulate`."""
    cfg = EngineConfig(
        protocol=protocol_params(protocol, **proto_over),
        costs=costs or CostModel(), workload=workload,
        n_threads=n_threads, horizon=horizon, p_abort=p_abort,
        drain=drain, seed=seed, attrib=attrib)
    stat, dp = split_config(cfg)
    tb0 = make_trace(cap, alloc=alloc, on=trace_on)
    s, tb, _ = run_traced(stat, dp, init_state_dyn(stat, dp), tb0)
    return s, tb


def events_host(tbuf: TraceBuf) -> dict:
    """Pull the stored prefix to host: numpy columns + counters."""
    n = int(tbuf.n)
    return {
        "ts": np.asarray(tbuf.ts)[:n],
        "tid": np.asarray(tbuf.tid)[:n],
        "row": np.asarray(tbuf.row)[:n],
        "ev": np.asarray(tbuf.ev)[:n],
        "n": n,
        "dropped": int(tbuf.dropped),
        "cap": int(tbuf.cap),
    }
