"""Shared compile accounting across the repo's jitted entry points.

Three layers, all opt-in and zero-cost when unused:

* **Cache counting** (``total_compiles()``) — sum of jit-cache sizes over
  every registered entry point. ``benchmarks/run.py`` records per-module
  deltas into BENCH_run.json so a recompile regression (a param
  accidentally promoted into the compile key) shows up on the perf
  trajectory instead of as mysterious wall time.
* **Compile telemetry** (``enable_telemetry``/``snapshot``/``delta``) —
  wall time actually spent in XLA backend compilation, counted via
  ``jax.monitoring`` duration events, plus per-function attribution
  parsed from jax's own "Finished XLA compilation of jit(name)" log line
  (captured silently at DEBUG level — nothing is printed). BENCH_run.json
  carries the per-module ``compile_time_s`` next to ``compiles``.
* **Strict cross-check** (``REPRO_COMPILE_STRICT=1``) — ``total_compiles``
  silently undercounts when a subsystem forgets to ``register()`` its
  jitted entry point; strict mode sweeps the heap for live repo-owned
  jit wrappers with non-empty caches that the accounting doesn't know
  about and warns with their names.

Subsystems with their own jitted entry points register them here
(idempotent); the core engine/aria/obs/kernels entry points are built
in. Instance-level jits register at construction time rather than
import time: ``launch/serve.py`` registers each ``GroupServer``'s
``_decode`` in ``__init__`` and ``launch/train.py`` registers its init
and train-step jits inside ``train()`` — so the accounting covers them
exactly while they are live, and a process that never builds them pays
nothing. The analysis linter (``repro.analysis.jaxpr_lint``) keeps its
entry-point registry mirrored against ``_jitted()``.
"""
from __future__ import annotations

import logging
import os
import re

_EXTRA: list = []


def register(fn) -> None:
    """Add a jitted function to the global compile accounting."""
    if fn not in _EXTRA:
        _EXTRA.append(fn)


def _jitted() -> list:
    # imported lazily: this module must stay importable before jax warms up
    from repro.core.lock import aria, engine
    from repro.obs import trace
    fns = [
        engine._run_dyn, engine._run_batch,
        engine._run_seg_dyn, engine._run_seg_batch,
        aria._run_dyn, aria._run_batch,
        aria._run_seg_dyn, aria._run_seg_batch,
        trace._run_traced,
    ]
    try:        # Pallas-backed entry points; optional on exotic hosts
        from repro.kernels.flash_attention import kernel as fk, ops as fo
        from repro.kernels.grouped_scatter import kernel as gk, ops as go
        fns += [fo.flash_attention, fk.flash_attention_bhsd,
                go.grouped_scatter_apply, gk.segment_sums]
    except Exception:
        pass
    return fns + list(_EXTRA)


def total_compiles() -> int:
    """Sum of jit-cache sizes over every registered entry point.

    With ``REPRO_COMPILE_STRICT=1`` also cross-checks the registry
    against every live repo-owned jit wrapper on the heap and warns
    (once per function) about any with compiles the sum missed.
    """
    total = 0
    for fn in _jitted():
        try:
            total += int(fn._cache_size())
        except Exception:      # cache API unavailable: count what we can
            pass
    if os.environ.get("REPRO_COMPILE_STRICT") == "1":
        strict_check()
    return total


# ---------------------------------------------------------------------------
# strict mode: find jitted repo functions the accounting doesn't know about
# ---------------------------------------------------------------------------

_STRICT_WARNED: set[str] = set()


def _owner_module(wrapper) -> str:
    wrapped = getattr(wrapper, "__wrapped__", None)
    return getattr(wrapped, "__module__", None) or ""


def unregistered_compiles(prefixes=("repro.", "benchmarks")) -> list[str]:
    """Names of live repo-owned jit wrappers with cached executables that
    ``total_compiles()`` is not counting. Heap sweep — call sparingly."""
    import gc
    known = {id(fn) for fn in _jitted()}
    out = []
    for obj in gc.get_objects():
        try:
            if not (hasattr(obj, "_cache_size") and hasattr(obj, "__wrapped__")):
                continue
            if id(obj) in known:
                continue
            mod = _owner_module(obj)
            if not mod.startswith(prefixes):
                continue
            if int(obj._cache_size()) > 0:
                out.append(f"{mod}.{getattr(obj, '__name__', repr(obj))}")
        except Exception:
            continue
    return sorted(set(out))


def strict_check(warn=None) -> list[str]:
    """Warn (once per name) about unregistered compiled entry points."""
    missing = unregistered_compiles()
    fresh = [m for m in missing if m not in _STRICT_WARNED]
    _STRICT_WARNED.update(fresh)
    for name in fresh:
        msg = (f"compile_log: unregistered jitted entry point with "
               f"compiled executables: {name} — total_compiles() is "
               f"undercounting; register() it")
        (warn or logging.getLogger(__name__).warning)(msg)
    return missing


# ---------------------------------------------------------------------------
# compile telemetry: wall time in XLA, per-function where attributable
# ---------------------------------------------------------------------------

_BACKEND_EVENT = "/jax/core/compile/backend_compile_duration"
_FINISHED_RE = re.compile(
    r"Finished XLA compilation of (?P<name>.+?) in (?P<secs>[0-9.eE+-]+) sec")

_TELE = {
    "enabled": False,
    "compile_time_s": 0.0,      # total secs in XLA backend compilation
    "backend_compiles": 0,      # number of backend compile events
    "fns": {},                  # "jit(name)" -> {"n": int, "secs": float}
}


def _on_duration(event: str, duration: float, **_kw) -> None:
    if event == _BACKEND_EVENT:
        _TELE["compile_time_s"] += float(duration)
        _TELE["backend_compiles"] += 1


class _FinishedHandler(logging.Handler):
    """Silently harvests per-function compile times from jax's own
    'Finished XLA compilation of jit(name) in S sec' debug line.

    Capture requires the dispatch logger at DEBUG with propagation off
    (else every debug line sprays stderr); records at INFO and above are
    re-dispatched to the root logger so real warnings still surface.
    """

    def emit(self, record: logging.LogRecord) -> None:
        try:
            if record.levelno > logging.DEBUG:
                logging.getLogger().handle(record)
            m = _FINISHED_RE.search(record.getMessage())
        except Exception:
            return
        if not m:
            return
        rec = _TELE["fns"].setdefault(m.group("name"), {"n": 0, "secs": 0.0})
        rec["n"] += 1
        rec["secs"] += float(m.group("secs"))


def enable_telemetry() -> bool:
    """Start recording compile wall time. Idempotent; returns enabled.

    Uses ``jax.monitoring`` duration events for totals (authoritative)
    and a DEBUG-level log capture on ``jax._src.dispatch`` for per-name
    attribution (best effort — the log line is jax-internal and absent
    on cache hits from the persistent compilation cache).
    """
    if _TELE["enabled"]:
        return True
    try:
        from jax import monitoring
        monitoring.register_event_duration_secs_listener(_on_duration)
    except Exception:
        return False
    try:
        lg = logging.getLogger("jax._src.dispatch")
        lg.setLevel(logging.DEBUG)
        lg.propagate = False
        lg.addHandler(_FinishedHandler(level=logging.DEBUG))
    except Exception:
        pass        # totals still work without per-name attribution
    _TELE["enabled"] = True
    return True


def snapshot() -> dict:
    """Current telemetry counters (enables telemetry on first use)."""
    enable_telemetry()
    return {
        "compile_time_s": _TELE["compile_time_s"],
        "backend_compiles": _TELE["backend_compiles"],
        "fns": {k: dict(v) for k, v in _TELE["fns"].items()},
        "compiles": total_compiles(),
    }


def delta(prev: dict) -> dict:
    """Telemetry delta since a previous :func:`snapshot`."""
    cur = snapshot()
    fns = {}
    for name, rec in cur["fns"].items():
        p = prev.get("fns", {}).get(name, {"n": 0, "secs": 0.0})
        dn, ds = rec["n"] - p["n"], rec["secs"] - p["secs"]
        if dn or ds > 1e-9:
            fns[name] = {"n": dn, "secs": round(ds, 4)}
    return {
        "compile_time_s": round(
            cur["compile_time_s"] - prev.get("compile_time_s", 0.0), 4),
        "backend_compiles":
            cur["backend_compiles"] - prev.get("backend_compiles", 0),
        "fns": fns,
        "compiles": cur["compiles"] - prev.get("compiles", 0),
    }


def hlo_module_bytes(compiled) -> int:
    """Size of a compiled executable's optimized HLO text, in bytes.

    Takes anything with ``as_text()`` (``jax.stages.Compiled`` or
    ``Lowered``); 0 when the backend can't render it.
    """
    try:
        return len(compiled.as_text().encode())
    except Exception:
        return 0
