"""Shared compile counter across the repo's jitted entry points.

Tests assert per-path compile counts locally (``fn._cache_size()``), but
nothing tracked the *global* compile total across a benchmark module —
a recompile regression (a param accidentally promoted into the compile
key) only surfaced as mysterious wall-time. ``benchmarks/run.py`` now
records ``total_compiles()`` deltas per module into BENCH_run.json so the
perf trajectory catches it directly.

Subsystems with their own jitted entry points register them here
(idempotent); the core engine/aria/obs entry points are built in.
"""
from __future__ import annotations

_EXTRA: list = []


def register(fn) -> None:
    """Add a jitted function to the global compile accounting."""
    if fn not in _EXTRA:
        _EXTRA.append(fn)


def _jitted() -> list:
    # imported lazily: this module must stay importable before jax warms up
    from repro.core.lock import aria, engine
    from repro.obs import trace
    return [
        engine._run_dyn, engine._run_batch,
        engine._run_seg_dyn, engine._run_seg_batch,
        aria._run_dyn, aria._run_batch,
        aria._run_seg_dyn, aria._run_seg_batch,
        trace._run_traced,
    ] + list(_EXTRA)


def total_compiles() -> int:
    """Sum of jit-cache sizes over every registered entry point."""
    total = 0
    for fn in _jitted():
        try:
            total += int(fn._cache_size())
        except Exception:      # cache API unavailable: count what we can
            pass
    return total
