"""Hotspot ranking from the per-record contention accumulator.

Consumes ``Globals.ca`` — the engine's on-device (N_CA, R) per-record
accumulator (DESIGN.md §14) — and turns it into the paper's hotspot
story: which records concentrate the waiting, how skewed the observed
contention is versus the workload's zipf ground truth, and which rows
the queue-length threshold rule (``core.hotspot``) would promote.

Conservation: the ``CA_WAIT`` lane charges exactly the ticks that charge
the TickBreakdown's ``lock_wait`` bin (cold+hot), so
:func:`check_ca_conservation` asserts the two totals equal — the
per-record twin of ``breakdown.check_conservation``, valid per run and
per governed segment (``delta_globals`` windows).
"""
from __future__ import annotations

import numpy as np

from repro.core.hotspot import DEFAULT_THRESHOLD, detect_hot_queue
from repro.core.lock.chop import zipf_weights
from repro.core.lock.engine import (CA_GRANTS, CA_NAMES, CA_QMAX, CA_QSUM,
                                    CA_TIMEOUTS, CA_VICTIMS, CA_WAIT,
                                    TB_LOCKWAIT)
from repro.core.lock.metrics import hotspot_rows


def _ca_of(obj) -> np.ndarray:
    """Accept a SimState, a Globals, or a raw (N_CA, R) array."""
    g = getattr(obj, "g", obj)
    ca = getattr(g, "ca", g)
    return np.asarray(ca, dtype=np.int64)


def check_ca_conservation(obj) -> int:
    """Assert sum of per-record wait ticks == TickBreakdown lock_wait.

    Both sides accumulate the identical per-iteration ``phase==WAIT``
    contributions (per row vs per branch-bin), so the identity is exact
    in i32. Accepts a SimState or Globals — including a
    ``delta_globals`` window, which makes it the per-governed-segment
    check too. Returns the common value. Attribution-off states pass
    only if lock_wait is also zero; check only attribution-on runs.
    """
    g = getattr(obj, "g", obj)
    got = int(_ca_of(g)[CA_WAIT].sum())
    want = int(np.asarray(g.tb, dtype=np.int64)[:, TB_LOCKWAIT].sum())
    if got != want:
        raise AssertionError(
            f"contention-conservation violated: sum(ca[wait])={got} != "
            f"tb[lock_wait]={want} (diff {got - want})")
    return got


def wait_share(obj) -> np.ndarray:
    """(R,) share of all lock-wait ticks charged to each record."""
    wait = _ca_of(obj)[CA_WAIT].astype(np.float64)
    total = wait.sum()
    return wait / total if total > 0 else wait


def gini(x) -> float:
    """Gini coefficient of a nonnegative vector (0 uniform, ->1 skewed)."""
    x = np.sort(np.asarray(x, dtype=np.float64))
    n = x.size
    total = x.sum()
    if n == 0 or total <= 0:
        return 0.0
    cum = np.cumsum(x)
    return float((n + 1 - 2.0 * cum.sum() / total) / n)


def top_share(obj, k: int = 1) -> float:
    """Share of all lock-wait ticks on the k most-waited records."""
    s = np.sort(wait_share(obj))[::-1]
    return float(s[:k].sum())


def hotspot_summary(obj, spec=None,
                    threshold: int = DEFAULT_THRESHOLD) -> dict:
    """Scalar hotspot metrics of a run (or delta window).

    ``spec`` (a WorkloadSpec) adds the ground-truth comparison: the Gini
    of the workload's zipf access weights over the same key space — how
    much of the observed contention skew is the workload's own skew and
    how much the protocol's amplification (lock waits concentrate harder
    than accesses under strict 2PL; group/brook flatten back toward it).
    """
    ca = _ca_of(obj)
    share = wait_share(ca)
    n_hot = int(np.asarray(
        detect_hot_queue(ca[CA_QMAX], threshold)).sum())
    out = {
        "wait_ticks": int(ca[CA_WAIT].sum()),
        "grants": int(ca[CA_GRANTS].sum()),
        "timeouts": int(ca[CA_TIMEOUTS].sum()),
        "victims": int(ca[CA_VICTIMS].sum()),
        "rows_waited": int((ca[CA_WAIT] > 0).sum()),
        "top1_share": float(np.sort(share)[::-1][:1].sum()),
        "top10_share": float(np.sort(share)[::-1][:10].sum()),
        "gini_wait": gini(ca[CA_WAIT]),
        "max_queue": int(ca[CA_QMAX].max()),
        "n_hot_rule": n_hot,
    }
    if spec is not None and getattr(spec, "kind", None) == "zipf":
        w = zipf_weights(spec.n_rows, spec.zipf_s)
        out["gini_zipf"] = gini(w)
        out["skew_amplification"] = (
            out["gini_wait"] / out["gini_zipf"] if out["gini_zipf"] else 0.0)
    return out


def hotspot_report(obj, spec=None, top_k: int = 10,
                   threshold: int = DEFAULT_THRESHOLD) -> str:
    """Text hotspot ranking: the contention accumulator made readable.

    Top-K records by wait ticks with their full accumulator lanes and
    wait share, the threshold rule's verdict per row, and the summary
    scalars (incl. the zipf ground-truth Gini when ``spec`` is given).
    """
    ca = _ca_of(obj)
    summ = hotspot_summary(ca, spec=spec, threshold=threshold)
    hot = np.asarray(detect_hot_queue(ca[CA_QMAX], threshold))
    share = wait_share(ca)
    lines = [
        f"# hotspot report: {summ['rows_waited']} records waited on, "
        f"{summ['wait_ticks']} wait ticks, "
        f"top-1 share {summ['top1_share']:.3f}, "
        f"gini {summ['gini_wait']:.3f}"
        + (f" (zipf ground truth {summ['gini_zipf']:.3f}, "
           f"amplification {summ['skew_amplification']:.2f}x)"
           if "gini_zipf" in summ else ""),
        f"# threshold rule (> {threshold} queued): "
        f"{summ['n_hot_rule']} rows promoted, "
        f"max observed queue {summ['max_queue']}",
        "row," + ",".join(CA_NAMES) + ",wait_share,hot",
    ]
    for r in hotspot_rows(ca, top_k):
        row = r["row"]
        cells = ",".join(str(r[k]) for k in CA_NAMES)
        lines.append(f"{row},{cells},{share[row]:.3f},"
                     f"{int(hot[row])}")
    return "\n".join(lines)


def hotspot_lane_events(trace_or_events, top_k: int = 4,
                        end: int | None = None) -> list:
    """Perfetto counter-track events for the hottest rows' queue depths.

    Derives each row's queue-depth timeline from the event stream (+1 at
    wait_enter, -1 when the wait resolves) and emits Chrome trace
    counter events ("ph":"C", one track per hot row, pid 1) for the
    ``top_k`` rows by queued ticks — the hotspot lanes of the trace
    export (consumed by ``export.to_chrome_trace``).
    """
    from .export import _as_events, _wait_spans
    ev = _as_events(trace_or_events)
    spans = list(_wait_spans(ev, end=end))
    qticks: dict = {}
    for _tid, row, t0, t1, _e in spans:
        qticks[row] = qticks.get(row, 0) + (t1 - t0)
    top = [r for r, _ in
           sorted(qticks.items(), key=lambda kv: -kv[1])[:top_k]]
    out = []
    for rank, row in enumerate(top):
        deltas: dict = {}
        for _tid, r, t0, t1, _e in spans:
            if r != row:
                continue
            deltas[t0] = deltas.get(t0, 0) + 1
            deltas[t1] = deltas.get(t1, 0) - 1
        depth = 0
        out.append({"ph": "M", "name": "thread_name", "pid": 1,
                    "tid": rank, "args": {"name": f"hotspot row {row}"}})
        for t in sorted(deltas):
            depth += deltas[t]
            out.append({"ph": "C", "name": f"qlen row {row}", "pid": 1,
                        "tid": rank, "ts": t / 10.0,
                        "args": {"queued": depth}})
    return out
