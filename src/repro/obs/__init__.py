"""Observability layer for the lock engine (opt-in, traced-flag gated).

Three parts (DESIGN.md §11):

* **Tick attribution** — the engine itself charges every thread-tick to a
  ``TickBreakdown`` bin (``Globals.tb``; exec / lock_wait / commit_wait /
  rollback / detection / sync / idle, split cold/hot); this package holds
  the conservation check (``sum(tb) == T * elapsed``) and report helpers.
* **Event tracing** (:mod:`.trace`) — a fixed-allocation on-device buffer
  capturing {tick, thread, row, event} inside the ``lax.while_loop``;
  capacity and the on-switch are traced data, so tracing never recompiles
  and ``trace_on=False`` is bit-exact with the untraced engine.
* **Export** (:mod:`.export`) — Chrome trace-event JSON (Perfetto) and
  text wait-profile / breakdown reports.

:mod:`.compile_log` is the shared compile counter + compile-time
telemetry benchmarks use to put recompile regressions on the perf
trajectory; :mod:`.prof` is the stage-ablation step profiler
(DESIGN.md §12) that attributes per-iteration wall cost to engine
stages.

**Hotspot attribution** (DESIGN.md §14): :mod:`.hotspot` ranks the
engine's per-record contention accumulator (``Globals.ca``, gated by
``EngineConfig.attrib``) into wait-share/Gini/threshold-rule reports and
asserts its conservation against the TickBreakdown; :mod:`.blame` pairs
TraceBuf wait spans with the holding transaction attempts into a blame
matrix, per-record blame table, and the longest blocking chain.
"""
from . import blame, breakdown, compile_log, export, hotspot, prof, trace
from .breakdown import (breakdown_row, check_conservation, fractions,
                        tick_sum)
from .prof import (STAGE_NOOPS, StageCost, StepProfile, profile_row,
                   profile_step, rank_table)
from .export import (breakdown_table, dump_chrome_trace, to_chrome_trace,
                     wait_profile)
from .blame import (BlameResult, blame_matrix, blame_table, critical_path)
from .hotspot import (check_ca_conservation, gini, hotspot_lane_events,
                      hotspot_report, hotspot_summary, top_share,
                      wait_share)
from .trace import (EVENTS, EV_ABORT, EV_COMMIT, EV_GRANT, EV_GROUP_JOIN,
                    EV_RELEASE, EV_TIMEOUT, EV_VICTIM, EV_WAIT_ENTER,
                    TraceBuf, events_host, make_trace, run_traced,
                    simulate_traced)

__all__ = [
    "blame", "breakdown", "compile_log", "export", "hotspot", "prof",
    "trace",
    "breakdown_row", "check_conservation", "fractions", "tick_sum",
    "STAGE_NOOPS", "StageCost", "StepProfile", "profile_row",
    "profile_step", "rank_table",
    "breakdown_table", "dump_chrome_trace", "to_chrome_trace",
    "wait_profile",
    "BlameResult", "blame_matrix", "blame_table", "critical_path",
    "check_ca_conservation", "gini", "hotspot_lane_events",
    "hotspot_report", "hotspot_summary", "top_share", "wait_share",
    "EVENTS", "EV_ABORT", "EV_COMMIT", "EV_GRANT", "EV_GROUP_JOIN",
    "EV_RELEASE", "EV_TIMEOUT", "EV_VICTIM", "EV_WAIT_ENTER",
    "TraceBuf", "events_host",
    "make_trace", "run_traced", "simulate_traced",
]
