"""Per-stage step profiler: where each engine iteration's wall time goes.

The engine step is one fused XLA program inside a ``lax.while_loop`` —
``jax.profiler`` spans cannot see inside it, and host timers only see the
whole iteration. Attribution therefore works by *stage ablation*
(DESIGN.md §12): for every stage in ``engine.PROF_STAGES`` we build a
step variant with that stage's compute replaced by its no-op stand-in
(``_make_step_events(..., ablate={stage})``), let XLA dead-code-eliminate
the stage, and difference steady-state per-iteration wall time against
the full step on the *same* warmed ``SimState`` input:

    cost(stage) ≈ us_per_iter(full) - us_per_iter(ablated)

Compile-key discipline matches the engine: the ablation set is static,
so each variant is exactly one executable (asserted), and the full
variant is byte-for-byte the production step. The stand-ins are chosen
so that under a designated no-op config the ablated step is *bit-exact*
with the full one (tests/test_prof.py) — the measured difference is
attributable to the stage's compute, not to semantic drift.

Caveats (DESIGN.md §12): XLA fuses across stage boundaries, so ablation
measures "what the program saves without this stage", which can exceed
or undercut a naive op-count share; negative diffs (noise on shared
fusions) clamp to zero and the unattributed remainder is reported as the
``other`` pseudo-stage, so fractions always sum to 1.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.lock import engine as _engine
from repro.core.lock.engine import (DynParams, EngineConfig, PROF_STAGES,
                                    SimState, StaticShape, init_state_dyn,
                                    split_config)
from repro.obs import compile_log

# Human-readable note per stage: the config under which its ablation is a
# bit-exact no-op (the parity contract tests/test_prof.py asserts), and
# what compute it removes. Keys == engine.PROF_STAGES.
STAGE_NOOPS = {
    "dup_analysis": "exact at txn_len == 1; removes the (T,L,L) pairwise "
                    "dup/last-use scan in gen_txn_dyn",
    "deadlock_walk": "exact when has_detection is off (o2/brook2pl); "
                     "removes the 8-hop waits-for cycle walk",
    "ticket_grant": "exact on a read-only workload (write_ratio=0); "
                    "removes grant masks + FIFO ticket argsort",
    "commit_cursor": "exact on a read-only workload; removes the T*L->R "
                     "segment reductions in _derive",
    "group_hotspot": "exact for protocols without group/hot flags "
                     "(mysql/brook2pl); removes the three lax.cond "
                     "branches",
    "tick_charge": "exact on all state except the write-only tb "
                   "accumulator; removes the TickBreakdown scatters",
}
assert set(STAGE_NOOPS) == set(PROF_STAGES)


@dataclasses.dataclass(frozen=True)
class StageCost:
    stage: str
    us_per_iter: float          # attributed cost (clamped >= 0)
    fraction: float             # of the full step; all rows sum to 1.0


@dataclasses.dataclass(frozen=True)
class StepProfile:
    protocol: str
    stat: StaticShape
    us_per_iter: float          # full-step steady-state per-iteration wall
    stages: tuple[StageCost, ...]   # ranked by cost desc, ends with residual
    n_iters: int
    repeats: int
    compiles: int               # executables built (len(stages_measured)+1)

    @property
    def dominant(self) -> StageCost:
        """Largest *real* stage (the residual never dominates a report)."""
        real = [s for s in self.stages if s.stage != "other"]
        return max(real, key=lambda s: s.us_per_iter)


def make_iter_runner(stat: StaticShape, dp: DynParams, n_iters: int,
                     ablate: frozenset = frozenset()):
    """Jit a ``SimState -> SimState`` running ``n_iters`` step iterations.

    One executable per (stat, n_iters, ablate) — the profiler's unit of
    measurement. Registered with :mod:`repro.obs.compile_log` so bench
    runs count profiler compiles like any other entry point.
    """
    step = _engine._make_step(stat, dp, ablate=ablate)

    @jax.jit
    def run(st: SimState) -> SimState:
        return jax.lax.fori_loop(0, n_iters, lambda _, s: step(s), st)

    compile_log.register(run)
    return run


def _block(st: SimState) -> None:
    for leaf in jax.tree_util.tree_leaves(st):
        leaf.block_until_ready()


def _time_us_per_iter(run, st: SimState, n_iters: int, repeats: int) -> float:
    """Best-of-``repeats`` per-iteration wall, first (compile) call excluded."""
    _block(run(st))             # compile + warm the executable
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(run(st))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6 / n_iters


def profile_step(cfg: EngineConfig, *, n_iters: int = 256,
                 warmup_rounds: int = 1, repeats: int = 3,
                 stages: Sequence[str] = PROF_STAGES) -> StepProfile:
    """Attribute the engine step's per-iteration wall cost to its stages.

    Builds one executable per ablation plus the full step, warms a
    steady-state ``SimState`` under the full step (``warmup_rounds`` x
    ``n_iters`` iterations — reusing the full executable keeps the
    compile count at exactly ``len(stages) + 1``), feeds the *same*
    state to every variant, and differences best-of-``repeats``
    ``us_per_iter``. The residual the ablations cannot explain is the
    ``other`` row; fractions sum to exactly 1.
    """
    unknown = set(stages) - set(PROF_STAGES)
    if unknown:
        raise ValueError(f"unknown stages: {sorted(unknown)}")
    stat, dp = split_config(cfg)
    st0 = init_state_dyn(stat, dp)

    full = make_iter_runner(stat, dp, n_iters)
    # warm into steady state so every variant sees live contention, not
    # the all-START first ticks
    warm = st0
    for _ in range(warmup_rounds):
        warm = full(warm)
    _block(warm)
    full_us = _time_us_per_iter(full, warm, n_iters, repeats)

    costs: dict[str, float] = {}
    n_exec = 1
    for stage in stages:
        run = make_iter_runner(stat, dp, n_iters, ablate=frozenset({stage}))
        n_exec += 1
        abl_us = _time_us_per_iter(run, warm, n_iters, repeats)
        costs[stage] = max(full_us - abl_us, 0.0)
        assert run._cache_size() == 1, \
            f"ablation {stage}: expected 1 executable, got {run._cache_size()}"
    assert full._cache_size() == 1

    other = max(full_us - sum(costs.values()), 0.0)
    total = sum(costs.values()) + other
    total = total or 1.0        # degenerate all-zero measurement
    ranked = sorted(costs.items(), key=lambda kv: -kv[1])
    rows = tuple(StageCost(k, v, v / total) for k, v in ranked)
    rows += (StageCost("other", other, other / total),)
    return StepProfile(protocol=cfg.protocol.name, stat=stat,
                       us_per_iter=full_us, stages=rows,
                       n_iters=n_iters, repeats=repeats, compiles=n_exec)


def rank_table(prof: StepProfile) -> str:
    """Ranked per-stage cost table, one profile per call."""
    s = prof.stat
    head = (f"step profile: {prof.protocol} T={s.n_threads} L={s.txn_len} "
            f"R={s.n_rows}  us_per_iter={prof.us_per_iter:.2f} "
            f"(n_iters={prof.n_iters}, best of {prof.repeats})")
    lines = [head, f"{'stage':<16}{'us/iter':>10}{'fraction':>10}"]
    for row in prof.stages:
        lines.append(f"{row.stage:<16}{row.us_per_iter:>10.3f}"
                     f"{row.fraction:>10.3f}")
    d = prof.dominant
    lines.append(f"dominant: {d.stage} ({d.fraction:.0%} of step)")
    return "\n".join(lines)


def profile_row(name: str, prof: StepProfile) -> str:
    """Benchmark CSV row ``name,us_per_iter,stage=frac;...;dominant=...``."""
    body = ";".join(f"{r.stage}={r.fraction:.4f}" for r in prof.stages)
    return (f"{name},{prof.us_per_iter:.3f},{body};"
            f"dominant={prof.dominant.stage};compiles={prof.compiles}")
