"""Trace export: Chrome trace-event JSON (Perfetto) + text wait profiles.

``to_chrome_trace`` emits the Trace Event Format that chrome://tracing and
https://ui.perfetto.dev load directly: each engine thread is a track,
lock waits are duration ("ph":"X") spans from wait_enter to the event
that resolved them (grant / timeout / deadlock_victim), and commits,
victims, releases and group joins are instants. Timestamps convert ticks
to microseconds (1 tick = 0.1us).

``wait_profile`` aggregates the same wait spans per row into the paper's
attribution story: the top-K hottest rows by queued ticks, with how each
wait ended. ``breakdown_table`` renders TickBreakdown fractions for a set
of protocols side by side.
"""
from __future__ import annotations

import json

from repro.core.lock.engine import TB_NAMES
from .breakdown import fractions
from .trace import (EVENTS, EV_ABORT, EV_GRANT, EV_WAIT_ENTER, EV_TIMEOUT,
                    EV_VICTIM, EV_RELEASE, EV_GROUP_JOIN, EV_COMMIT,
                    TraceBuf, events_host)


def _as_events(trace_or_events) -> dict:
    if isinstance(trace_or_events, TraceBuf):
        return events_host(trace_or_events)
    return trace_or_events


_WAIT_END = (EV_GRANT, EV_TIMEOUT, EV_VICTIM)


def _wait_spans(ev: dict, end: int | None = None):
    """Pair wait_enter with the event that resolved it, per thread.

    Yields (tid, row, t0, t1, end_ev). The buffer is time-ordered and a
    thread has at most one wait open at a time, so a single forward scan
    suffices. Waits still open at the end of the capture window close at
    ``end`` (default: last recorded tick) with end_ev None.
    """
    open_by_tid: dict = {}
    for i in range(ev["n"]):
        t, tid, row, e = (int(ev["ts"][i]), int(ev["tid"][i]),
                          int(ev["row"][i]), int(ev["ev"][i]))
        if e == EV_WAIT_ENTER:
            open_by_tid[tid] = (row, t)
        elif e in _WAIT_END and tid in open_by_tid:
            row0, t0 = open_by_tid.pop(tid)
            yield tid, row0, t0, t, e
    if open_by_tid:
        tail = int(ev["ts"][ev["n"] - 1]) if ev["n"] else 0
        close = tail if end is None else int(end)
        for tid, (row0, t0) in sorted(open_by_tid.items()):
            yield tid, row0, t0, max(close, t0), None


def to_chrome_trace(trace_or_events, label: str = "lock-engine",
                    end: int | None = None,
                    hotspot_lanes: int = 0) -> dict:
    """Chrome trace-event JSON document (dict; json.dump it yourself or
    use :func:`dump_chrome_trace`). Valid for Perfetto / chrome://tracing.

    ``hotspot_lanes`` > 0 adds one counter track ("ph":"C", pid 1) per
    hottest row showing its wait-queue depth over time — the per-record
    contention picture beside the per-thread spans (DESIGN.md §14).
    """
    ev = _as_events(trace_or_events)
    us = lambda ticks: ticks / 10.0
    out = [{"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
            "args": {"name": label}}]
    for tid in sorted({int(t) for t in ev["tid"]}):
        out.append({"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                    "args": {"name": f"worker-{tid}"}})
    for tid, row, t0, t1, e in _wait_spans(ev, end=end):
        out.append({
            "ph": "X", "name": f"wait row {row}", "cat": "lock_wait",
            "pid": 0, "tid": tid, "ts": us(t0), "dur": us(t1 - t0),
            "args": {"row": row,
                     "end": EVENTS[e] if e is not None else "open"}})
    instants = {EV_COMMIT: "commit", EV_VICTIM: "deadlock_victim",
                EV_TIMEOUT: "timeout", EV_RELEASE: "early_release",
                EV_GROUP_JOIN: "group_join", EV_ABORT: "abort"}
    for i in range(ev["n"]):
        e = int(ev["ev"][i])
        if e not in instants:
            continue
        rec = {"ph": "i", "name": instants[e], "cat": "lock_event",
               "pid": 0, "tid": int(ev["tid"][i]),
               "ts": us(int(ev["ts"][i])), "s": "t"}
        if int(ev["row"][i]) >= 0:
            rec["args"] = {"row": int(ev["row"][i])}
        out.append(rec)
    if hotspot_lanes > 0:
        from .hotspot import hotspot_lane_events
        out.extend(hotspot_lane_events(ev, top_k=hotspot_lanes, end=end))
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "otherData": {"events_stored": ev["n"], "dropped": ev["dropped"],
                      "capacity": ev["cap"]},
    }


def dump_chrome_trace(path: str, trace_or_events, **kw) -> str:
    doc = to_chrome_trace(trace_or_events, **kw)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return path


def wait_profile(trace_or_events, top_k: int = 10,
                 end: int | None = None) -> str:
    """Top-K hottest rows by queued ticks (text report).

    One line per row: total queued ticks across all waits on it, wait
    count, and how those waits ended (granted / timed out / victimized /
    still open). A dropped-events warning heads the report when the
    capture truncated — the profile is then a lower bound.
    """
    ev = _as_events(trace_or_events)
    qticks: dict = {}
    ends: dict = {}
    for _tid, row, t0, t1, e in _wait_spans(ev, end=end):
        qticks[row] = qticks.get(row, 0) + (t1 - t0)
        key = EVENTS[e] if e is not None else "open"
        ends.setdefault(row, {})[key] = ends.get(row, {}).get(key, 0) + 1
    lines = []
    if ev["dropped"]:
        lines.append(f"# WARNING: {ev['dropped']} events dropped at "
                     f"capacity {ev['cap']} — profile is a lower bound")
    lines.append(f"# wait profile: {len(qticks)} rows with waits, "
                 f"top {min(top_k, len(qticks))} by queued ticks")
    lines.append("row,queued_ticks,queued_us,waits,grant,timeout,"
                 "deadlock_victim,open")
    ranked = sorted(qticks.items(), key=lambda kv: -kv[1])[:top_k]
    for row, ticks in ranked:
        e = ends.get(row, {})
        waits = sum(e.values())
        lines.append(
            f"{row},{ticks},{ticks / 10.0:.1f},{waits},"
            f"{e.get('grant', 0)},{e.get('timeout', 0)},"
            f"{e.get('deadlock_victim', 0)},{e.get('open', 0)}")
    return "\n".join(lines)


def breakdown_table(results: dict) -> str:
    """Side-by-side TickBreakdown fractions, one line per protocol.

    ``results`` maps a label to a :class:`SimResult` (or any object with a
    ``breakdown`` dict). Fractions of total thread-ticks, so each line
    sums to 1 — the conservation invariant rendered human-readable.
    """
    width = max([len(k) for k in results] + [8])
    head = " ".join(f"{n:>11}" for n in TB_NAMES)
    lines = [f"{'protocol':<{width}} {head}"]
    for name, r in results.items():
        fr = fractions(getattr(r, "breakdown", r))
        cells = " ".join(f"{fr.get(n, 0.0):>11.3f}" for n in TB_NAMES)
        lines.append(f"{name:<{width}} {cells}")
    return "\n".join(lines)
