"""Shared layers: norms, dense/SwiGLU MLP, rotary embeddings (+M-RoPE)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import spec


# -------------------------------------------------------------- RMSNorm

def rmsnorm_spec(d: int):
    return {"scale": spec((d,), (None,), init="ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


# -------------------------------------------------------------- MLP (GLU)

def mlp_spec(d: int, ff: int):
    return {
        "wi_gate": spec((d, ff), ("embed", "mlp")),
        "wi_up": spec((d, ff), ("embed", "mlp")),
        "wo": spec((ff, d), ("mlp", "embed")),
    }


def mlp(p, x, act=jax.nn.silu):
    gate = act(jnp.einsum("...d,df->...f", x, p["wi_gate"].astype(x.dtype)))
    up = jnp.einsum("...d,df->...f", x, p["wi_up"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", gate * up, p["wo"].astype(x.dtype))


# -------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    ang = ang[..., None, :]                              # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                theta: float = 1e4,
                sections=(16, 24, 24)) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: rotary over 3 position streams (t, h, w).

    x: (B, S, H, D); positions3: (3, B, S). ``sections`` are per-stream
    frequency-pair counts summing to D/2 (scaled to D below).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)                         # (half,)
    # partition the half-dim frequency slots into the 3 sections
    sec = jnp.asarray(sections, jnp.int32)
    sec = (sec * half) // sec.sum()
    bounds = jnp.cumsum(sec)
    slot = jnp.arange(half)
    which = (slot[None, :] >= jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), bounds[:-1]])[:, None]) & \
        (slot[None, :] < bounds[:, None])               # (3, half)
    # per-slot position: pick the stream owning this slot
    pos = jnp.einsum("kbs,kf->bsf", positions3.astype(jnp.float32),
                     which.astype(jnp.float32))          # (B, S, half)
    ang = pos[..., None, :] * freqs                      # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- embedding

def embed_spec(vocab: int, d: int):
    return {"table": spec((vocab, d), ("vocab", "embed"), init="embed")}


def embed(p, tokens):
    return p["table"][tokens]


def unembed_spec(d: int, vocab: int, n_heads: int = 1):
    if n_heads > 1:
        return {"w": spec((n_heads, d, vocab), (None, "embed", "vocab"),
                          fan_in_axes=(1,))}
    return {"w": spec((d, vocab), ("embed", "vocab"))}


def unembed(p, x):
    w = p["w"]
    if w.ndim == 3:
        return jnp.einsum("...d,kdv->...kv", x, w.astype(x.dtype))
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
