"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (the paper's "recurrent block"):
  x -> [linear -> gelu] (gate branch)
  x -> [linear -> conv1d(w=4) -> RG-LRU] (recurrent branch)
  out = (gate * rec) -> linear

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_a x_t + b_a)            recurrence gate
  i_t = sigmoid(W_x x_t + b_x)            input gate
  a_t = exp(-c * softplus(L) * r_t)       log-space decay, c = 8
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill runs the recurrence as an associative scan over time; decode
carries (h, conv window) state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import spec

C_RGLRU = 8.0


def rglru_spec(cfg):
    d, w = cfg.d_model, cfg.lru_width
    cw = cfg.conv_width
    return {
        "w_gate": spec((d, w), ("embed", "lru")),
        "w_in": spec((d, w), ("embed", "lru")),
        "conv": spec((cw, w), (None, "lru"), init="dense"),
        "w_a": spec((w, w), ("lru", "lru")),
        "b_a": spec((w,), ("lru",), init="zeros"),
        "w_x": spec((w, w), ("lru", "lru")),
        "b_x": spec((w,), ("lru",), init="zeros"),
        "log_lambda": spec((w,), ("lru",), init="value", value=0.5),
        "w_out": spec((w, d), ("lru", "embed")),
    }


class RGLRUState(NamedTuple):
    h: jnp.ndarray         # (B, W) recurrent state
    conv: jnp.ndarray      # (B, conv_width-1, W) conv tail


def _gates(p, u):
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u,
                                  p["w_a"].astype(u.dtype))
                       + p["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u,
                                  p["w_x"].astype(u.dtype))
                       + p["b_x"].astype(u.dtype))
    lam = jax.nn.softplus(p["log_lambda"].astype(jnp.float32))
    log_a = -C_RGLRU * lam * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, gated


def _conv1d(p, u, state=None):
    """Causal depthwise conv along time. u: (B, S, W)."""
    cw = p["conv"].shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * p["conv"][i].astype(u.dtype)
              for i in range(cw))
    return out, full[:, -(cw - 1):] if cw > 1 else pad


def rglru(p, x, cfg, mode: str, state: RGLRUState | None = None):
    """x: (B, S, d) -> (out, new_state|None)."""
    B, S, d = x.shape
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x,
                                  p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"].astype(x.dtype))

    if mode in ("train", "prefill"):
        u, conv_tail = _conv1d(p, u)
        a, gated = _gates(p, u)
        # h_t = a_t h_{t-1} + gated_t  — associative scan over time
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2
        aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
        h = hh
        out = jnp.einsum("bsw,wd->bsd", (h * gate.astype(jnp.float32))
                         .astype(x.dtype), p["w_out"].astype(x.dtype))
        new_state = None
        if mode == "prefill":
            new_state = RGLRUState(h=h[:, -1].astype(jnp.float32),
                                   conv=conv_tail.astype(jnp.float32))
        return out, new_state

    # decode: single step
    assert state is not None
    u, conv_tail = _conv1d(p, u, state.conv)
    a, gated = _gates(p, u)
    h = a[:, 0] * state.h + gated[:, 0]
    out = jnp.einsum("bw,wd->bd", (h * gate[:, 0].astype(jnp.float32))
                     .astype(x.dtype), p["w_out"].astype(x.dtype))
    return out[:, None], RGLRUState(h=h, conv=conv_tail.astype(jnp.float32))
