"""Attention mixers: GQA (global + sliding-window) and MLA (DeepSeek-V2).

Modes:
  * ``train`` / ``prefill``: full-sequence causal attention (optionally
    sliding-window). Prefill additionally returns the KV cache.
  * ``decode``: one query token against a cache. Sliding-window layers use a
    **ring-buffer cache** of ``window`` slots (this is what makes gemma3 /
    recurrentgemma long_500k decodes memory-feasible); global layers keep
    the full context. MLA decodes through the **absorbed** formulation
    (scores and values in the 512-d latent space; the per-head K/V
    up-projections are folded into the query / output projections), so the
    latent cache is never expanded at decode time.

The dense-path attention math is also available as a Pallas flash kernel
(``repro.kernels.flash_attention``); `use_kernel` switches (tests compare
both).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import spec
from .layers import apply_rope, apply_mrope

NEG_INF = -2.0e38


# ===========================================================================
# GQA
# ===========================================================================

def gqa_spec(cfg):
    d, hd = cfg.d_model, cfg.hd
    s = {
        "wq": spec((d, cfg.n_heads * hd), ("embed", "heads")),
        "wk": spec((d, cfg.n_kv_heads * hd), ("embed", "kv")),
        "wv": spec((d, cfg.n_kv_heads * hd), ("embed", "kv")),
        "wo": spec((cfg.n_heads * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = spec((cfg.n_heads * hd,), ("heads",), init="zeros")
        s["bk"] = spec((cfg.n_kv_heads * hd,), ("kv",), init="zeros")
        s["bv"] = spec((cfg.n_kv_heads * hd,), ("kv",), init="zeros")
    return s


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_cache, K, D)
    v: jnp.ndarray        # (B, S_cache, K, D)


def gqa_cache_len(cfg, kind: str, seq_len: int) -> int:
    return min(seq_len, cfg.window) if kind == "local" else seq_len


def _qkv(p, x, cfg):
    B, S, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """Grouped scaled-dot-product attention. q: (B,Sq,H,Dk); k: (B,Sk,K,Dk);
    v: (B,Sk,K,Dv) (Dv may differ — MLA).

    mask: broadcastable to (B, 1, Sq, Sk) (True = attend).
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    Dv = v.shape[3]
    G = H // K
    q = q.reshape(B, Sq, K, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H * Dv)


def _causal_mask(Sq, Sk, window: Optional[int] = None, offset: int = 0):
    """(Sq, Sk) mask; offset = (#k positions preceding the q block)."""
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _sdpa_chunked(q, k, v, scale, window: Optional[int], chunk: int):
    """Query-block-chunked causal attention: the (Sq, Sk) score matrix
    exists one (chunk, Sk) slab at a time; the slab is rematerialized in
    the backward pass (jax.checkpoint) — flash attention's memory behavior
    expressed at the XLA level (the Pallas kernel is the TPU-native
    version; this path is what the SPMD dry-run lowers).
    """
    B, Sq, H, D = q.shape
    assert Sq % chunk == 0, (Sq, chunk)
    nb = Sq // chunk
    qb = q.reshape(B, nb, chunk, H, D).swapaxes(0, 1)   # (nb, B, c, H, D)

    @jax.checkpoint
    def body(carry, args):
        qi, blk = args
        mask = (_causal_mask(chunk, k.shape[1], window,
                             offset=qi * chunk))[None, None]
        out = _sdpa(blk, k, v, mask, scale)             # (B, c, H*D)
        return carry, out

    _, outs = jax.lax.scan(body, (),
                           (jnp.arange(nb, dtype=jnp.int32), qb))
    return outs.swapaxes(0, 1).reshape(B, Sq, outs.shape[-1])


def _pad_seq(arr, target: int, axis: int = 1):
    if arr.shape[axis] >= target:
        return arr
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, target - arr.shape[axis])
    return jnp.pad(arr, pad)


def gqa_attend(p, x, cfg, kind: str, mode: str,
               positions=None, cache: Optional[KVCache] = None,
               pos=None, positions3=None, use_kernel: bool = False,
               max_len: Optional[int] = None):
    """Returns (out, new_cache|None). ``max_len``: prefill cache capacity
    (a serving runtime preallocates room for the tokens to be decoded)."""
    B, S, _ = x.shape
    hd = cfg.hd
    scale = hd ** -0.5
    window = cfg.window if kind == "local" else None

    if mode in ("train", "prefill"):
        q, k, v = _qkv(p, x, cfg)
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        if cfg.mrope and positions3 is not None:
            q = apply_mrope(q, positions3, cfg.rope_theta)
            k = apply_mrope(k, positions3, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        if use_kernel and window is None:
            from repro.kernels.flash_attention.ops import flash_attention
            out = flash_attention(q, k, v, causal=True, scale=scale)
            out = out.reshape(B, S, cfg.n_heads * hd)
        elif cfg.attn_chunk and S > cfg.attn_chunk:
            out = _sdpa_chunked(q, k, v, scale, window, cfg.attn_chunk)
        else:
            mask = _causal_mask(S, S, window)[None, None]
            out = _sdpa(q, k, v, mask, scale)
        out = jnp.einsum("bsh,hd->bsd", out.astype(x.dtype),
                         p["wo"].astype(x.dtype))
        new_cache = None
        if mode == "prefill":
            cap = gqa_cache_len(cfg, kind, max_len or S)
            cl = min(gqa_cache_len(cfg, kind, S), cap)
            kt, vt = k[:, -cl:], v[:, -cl:]
            if window is not None and cl == window:
                # ring order: absolute position p lives at slot p % window
                kt = jnp.roll(kt, shift=S % window, axis=1)
                vt = jnp.roll(vt, shift=S % window, axis=1)
            new_cache = KVCache(k=_pad_seq(kt, cap), v=_pad_seq(vt, cap))
        return out, new_cache

    # ----------------------------------------------------------- decode
    assert cache is not None and pos is not None
    q, k, v = _qkv(p, x, cfg)                    # S == 1
    posb = jnp.broadcast_to(pos, (B,))[:, None]
    if cfg.mrope and positions3 is not None:
        q = apply_mrope(q, positions3, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.rope_theta)
    else:
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
    Sc = cache.k.shape[1]
    slot = (pos % Sc).astype(jnp.int32)
    # write the single new position at `slot`
    nk = cache.k.at[:, slot].set(k[:, 0].astype(cache.k.dtype))
    nv = cache.v.at[:, slot].set(v[:, 0].astype(cache.v.dtype))
    kpos = jnp.arange(Sc, dtype=jnp.int32)
    if window is None:
        valid = kpos <= pos
    else:
        # ring buffer: slot i holds absolute position with i = abs % Sc
        abs_pos = pos - ((slot - kpos) % Sc)
        valid = (abs_pos >= 0) & (abs_pos >= pos - window + 1)
    mask = valid[None, None, None, :]
    out = _sdpa(q, nk, nv, mask[:, 0], scale)
    out = jnp.einsum("bsh,hd->bsd", out.astype(x.dtype),
                     p["wo"].astype(x.dtype))
    return out, KVCache(k=nk, v=nv)


# ===========================================================================
# MLA (DeepSeek-V2 multi-head latent attention)
# ===========================================================================

def mla_spec(cfg):
    d = cfg.d_model
    H = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": spec((d, H * qk), ("embed", "heads")),
        "w_dkv": spec((d, cfg.kv_lora_rank + cfg.qk_rope_dim),
                      ("embed", "state")),
        "kv_norm": spec((cfg.kv_lora_rank,), (None,), init="ones"),
        "w_uk": spec((cfg.kv_lora_rank, H * cfg.qk_nope_dim),
                     ("state", "heads")),
        "w_uv": spec((cfg.kv_lora_rank, H * cfg.v_head_dim),
                     ("state", "heads")),
        "wo": spec((H * cfg.v_head_dim, d), ("heads", "embed")),
    }


class MLACache(NamedTuple):
    ckv: jnp.ndarray      # (B, S, kv_lora_rank)
    krope: jnp.ndarray    # (B, S, qk_rope_dim)


def _mla_qkv_latent(p, x, cfg):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    dkv = jnp.einsum("bsd,dh->bsh", x, p["w_dkv"].astype(x.dtype))
    ckv, krope = dkv[..., :cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    # RMS-normalize the latent (as in DeepSeek-V2)
    var = jnp.mean(jnp.square(ckv.astype(jnp.float32)), -1, keepdims=True)
    ckv = (ckv.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
           * p["kv_norm"].astype(jnp.float32)).astype(x.dtype)
    return q_nope, q_rope, ckv, krope


def mla_attend(p, x, cfg, mode: str, positions=None,
               cache: Optional[MLACache] = None, pos=None,
               max_len: Optional[int] = None):
    B, S, _ = x.shape
    H, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    R = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5
    q_nope, q_rope, ckv, krope = _mla_qkv_latent(p, x, cfg)

    if mode in ("train", "prefill"):
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        krope_r = apply_rope(krope[:, :, None, :], positions,
                             cfg.rope_theta)[:, :, 0]
        k_nope = jnp.einsum("bsr,rh->bsh", ckv,
                            p["w_uk"].astype(x.dtype)).reshape(B, S, H, dn)
        v = jnp.einsum("bsr,rh->bsh", ckv,
                       p["w_uv"].astype(x.dtype)).reshape(B, S, H, dv)
        # concat trick: [q_nope; q_rope] . [k_nope; k_rope] — one GQA-style
        # attention (K == H), so the chunked path is shared.
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_r[:, :, None, :],
                                      (B, S, H, dr)).astype(k_nope.dtype)],
            axis=-1)
        if cfg.attn_chunk and S > cfg.attn_chunk:
            out = _sdpa_chunked(q_cat, k_cat, v, scale, None,
                                cfg.attn_chunk)
        else:
            mask = _causal_mask(S, S)[None, None]
            out = _sdpa(q_cat, k_cat, v, mask, scale)
        out = jnp.einsum("bsh,hd->bsd", out.astype(x.dtype),
                         p["wo"].astype(x.dtype))
        new_cache = None
        if mode == "prefill":
            cap = max_len or S
            new_cache = MLACache(ckv=_pad_seq(ckv, cap),
                                 krope=_pad_seq(krope_r, cap))
        return out, new_cache

    # -------------------------------------------------- decode (absorbed)
    assert cache is not None and pos is not None
    posb = jnp.broadcast_to(pos, (B,))[:, None]
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta)
    krope_r = apply_rope(krope[:, :, None, :], posb, cfg.rope_theta)[:, :, 0]
    nckv = cache.ckv.at[:, pos].set(ckv[:, 0].astype(cache.ckv.dtype))
    nkrope = cache.krope.at[:, pos].set(krope_r[:, 0].astype(
        cache.krope.dtype))
    Sc = nckv.shape[1]
    # absorb W_uk into the query: q_lat (B,1,H,R)
    w_uk = p["w_uk"].reshape(R, H, dn)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat,
                         nckv.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                           nkrope.astype(jnp.float32))) * scale
    valid = jnp.arange(Sc, dtype=jnp.int32) <= pos
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", w, nckv.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(R, H, dv)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * dv)
    out = jnp.einsum("bsh,hd->bsd", out.astype(x.dtype),
                     p["wo"].astype(x.dtype))
    return out, MLACache(ckv=nckv, krope=nkrope)
