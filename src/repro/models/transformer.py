"""Block assembly + layer-group scan machinery.

Each ``LayerGroup = (unit, repeats)`` compiles to one ``lax.scan`` over
``repeats`` with the unit's parameters stacked on a leading "layers" axis.
Caches/states are scanned alongside as xs/ys. Remat wraps the unit body.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .common import spec, stack_specs
from .layers import rmsnorm_spec, rmsnorm, mlp_spec, mlp
from .attention import (gqa_spec, gqa_attend, gqa_cache_len, KVCache,
                        mla_spec, mla_attend, MLACache)
from .rglru import rglru_spec, rglru, RGLRUState
from .ssd import ssd_spec, ssd, SSDState


# --------------------------------------------------------------- specs

def block_spec(cfg, kind):
    mixer, mlp_kind = kind
    d = cfg.d_model
    s = {"ln1": rmsnorm_spec(d)}
    if mixer in ("global", "local"):
        s["attn"] = gqa_spec(cfg)
    elif mixer == "mla":
        s["attn"] = mla_spec(cfg)
    elif mixer == "rglru":
        s["attn"] = rglru_spec(cfg)
    elif mixer == "ssd":
        s["attn"] = ssd_spec(cfg)
    else:  # pragma: no cover
        raise ValueError(mixer)
    if mlp_kind != "none":
        s["ln2"] = rmsnorm_spec(d)
        if mlp_kind in ("dense", "moe+dense"):
            s["mlp"] = mlp_spec(d, cfg.d_ff)
        if mlp_kind in ("moe", "moe+dense"):
            from .moe import moe_spec
            s["moe"] = moe_spec(cfg)
    return s


def group_spec(cfg, unit, repeats):
    return {f"u{i}": stack_specs(block_spec(cfg, kind), repeats, "layers")
            for i, kind in enumerate(unit)}


def lm_block_specs(cfg):
    return {f"g{gi}": group_spec(cfg, unit, reps)
            for gi, (unit, reps) in enumerate(cfg.layout)}


# --------------------------------------------------------------- caches

def block_cache_shape(cfg, kind, batch: int, seq_len: int, dtype):
    """ShapeDtypeStructs for one layer's decode cache."""
    mixer = kind[0]
    hd = cfg.hd
    if mixer in ("global", "local"):
        cl = gqa_cache_len(cfg, mixer, seq_len)
        sh = (batch, cl, cfg.n_kv_heads, hd)
        return KVCache(k=jax.ShapeDtypeStruct(sh, dtype),
                       v=jax.ShapeDtypeStruct(sh, dtype))
    if mixer == "mla":
        return MLACache(
            ckv=jax.ShapeDtypeStruct((batch, seq_len, cfg.kv_lora_rank),
                                     dtype),
            krope=jax.ShapeDtypeStruct((batch, seq_len, cfg.qk_rope_dim),
                                       dtype))
    if mixer == "rglru":
        w = cfg.lru_width
        return RGLRUState(
            h=jax.ShapeDtypeStruct((batch, w), jnp.float32),
            conv=jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w),
                                      jnp.float32))
    if mixer == "ssd":
        return SSDState(
            h=jax.ShapeDtypeStruct(
                (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32),
            conv=jax.ShapeDtypeStruct(
                (batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state),
                jnp.float32))
    raise ValueError(mixer)  # pragma: no cover


def _stack_struct(tree, n: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def lm_cache_shapes(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Abstract cache tree for the whole model (dry-run input)."""
    return {
        f"g{gi}": {
            f"u{i}": _stack_struct(
                block_cache_shape(cfg, kind, batch, seq_len, dtype), reps)
            for i, kind in enumerate(unit)}
        for gi, (unit, reps) in enumerate(cfg.layout)}


def lm_init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    shapes = lm_cache_shapes(cfg, batch, seq_len, dtype)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


# --------------------------------------------------------------- apply

def block_apply(p, x, cfg, kind, mode, cache=None, pos=None,
                positions3=None, use_kernel=False, max_len=None):
    """One block. Returns (x, new_cache, aux_loss)."""
    mixer, mlp_kind = kind
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mixer in ("global", "local"):
        out, ncache = gqa_attend(p["attn"], h, cfg, mixer, mode,
                                 cache=cache, pos=pos, positions3=positions3,
                                 use_kernel=use_kernel, max_len=max_len)
    elif mixer == "mla":
        out, ncache = mla_attend(p["attn"], h, cfg, mode, cache=cache,
                                 pos=pos, max_len=max_len)
    elif mixer == "rglru":
        out, ncache = rglru(p["attn"], h, cfg, mode, state=cache)
    else:  # ssd
        out, ncache = ssd(p["attn"], h, cfg, mode, state=cache)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if mlp_kind != "none":
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        y = jnp.zeros_like(x)
        if "mlp" in p:
            y = y + mlp(p["mlp"], h)
        if "moe" in p:
            from .moe import moe
            ym, stats = moe(p["moe"], h, cfg)
            aux = aux + stats.aux_loss
            y = y + ym
        x = x + y
    return x, ncache, aux


def group_apply_layers(p, x, cfg, unit, mode, caches=None, pos=None,
                       positions3=None, use_kernel=False, remat=True,
                       max_len=None):
    """Scan one layer group. caches: pytree with leading `repeats` axis.

    Returns (x, new_caches|None, aux_sum).
    """
    has_cache = mode in ("prefill", "decode")

    def unit_body(x, layer_params, layer_caches):
        from repro.distributed.sharding import annotate
        # sequence parallelism at the block boundary: the residual stream
        # (and thus the remat-scan's saved carries) is sharded over the
        # model axis along the sequence; attention/MLP gather what they
        # need (Megatron-SP collectives, inserted by SPMD). 16x smaller
        # per-device activation checkpoints for 62-layer models.
        x = annotate(x, "batch", "model", None)
        aux_sum = jnp.zeros((), jnp.float32)
        new_caches = {}
        for i, kind in enumerate(unit):
            c = layer_caches[f"u{i}"] if layer_caches is not None else None
            x, nc, aux = block_apply(layer_params[f"u{i}"], x, cfg, kind,
                                     mode, cache=c, pos=pos,
                                     positions3=positions3,
                                     use_kernel=use_kernel, max_len=max_len)
            new_caches[f"u{i}"] = nc
            aux_sum = aux_sum + aux
        return x, (new_caches if has_cache else None), aux_sum

    if remat and mode == "train":
        unit_body = jax.checkpoint(
            unit_body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.unroll_layers:
        # python-unrolled path (exact per-layer cost probes; also usable
        # for small models where scan overhead dominates)
        n_reps = jax.tree.leaves(p)[0].shape[0]
        aux_total = jnp.zeros((), jnp.float32)
        caches_out = []
        for r in range(n_reps):
            lp = jax.tree.map(lambda a: a[r], p)
            lc = (jax.tree.map(lambda a: a[r], caches)
                  if caches is not None else None)
            x, nc, a = unit_body(x, lp, lc)
            caches_out.append(nc)
            aux_total = aux_total + a
        if has_cache and caches_out[0] is not None:
            caches_out = jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *caches_out)
        else:
            caches_out = None
        return x, caches_out, aux_total

    if mode == "train":
        def scan_fn(carry, layer_params):
            x, aux = carry
            x, _, a = unit_body(x, layer_params, None)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                                   p)
        return x, None, aux

    if mode == "prefill":
        def scan_fn(carry, layer_params):
            x, aux = carry
            x, ncaches, a = unit_body(x, layer_params, None)
            return (x, aux + a), ncaches
        (x, aux), caches_out = jax.lax.scan(
            scan_fn, (x, jnp.zeros((), jnp.float32)), p)
        return x, caches_out, aux

    # decode: caches are xs AND ys
    def scan_fn(carry, xs):
        x, aux = carry
        layer_params, layer_caches = xs
        x, ncaches, a = unit_body(x, layer_params, layer_caches)
        return (x, aux + a), ncaches
    (x, aux), caches_out = jax.lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)), (p, caches))
    return x, caches_out, aux
