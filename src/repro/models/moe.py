"""Mixture-of-Experts with conflict-group dispatch (paper §3.3 adapted).

Token->expert routing is the MoE instance of the hotspot problem: tokens
"contend" for an expert's weights. The dispatch below is exactly the
paper's group-locking schedule on tensors:

  1. stable-sort the (token, k) assignments by expert id — conflict-group
     formation; the sort order is the dependency list (``hot_update_order``);
  2. each group executes as ONE dense batched matmul — the group's members
     ("followers") need no further synchronization;
  3. one gather in / one scatter out per group — the leader's single lock
     acquire/release.

Distribution: the token axis carries an explicit leading shard dimension
(``cfg.moe_data_shards``, set to the mesh's data-parallel size by the
launcher) so the capacity grid is **per data shard**; the grid's expert
axis is annotated to the "model" mesh axis (EP). XLA then lowers dispatch/
combine to all-to-alls over shard-local capacity instead of global grids.

Capacity overflow (rank >= C within a group) drops to the residual stream
— the analogue of the timeout abort; `suggest_capacity` implements the
§4.6.1 dynamic-batch-size analogue (host-side capacity feedback from the
expert-load EMA, since shapes must stay static inside one XLA program).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import spec
from .layers import mlp_spec, mlp


def moe_spec(cfg):
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    s = {
        "router": spec((d, E), ("embed", "experts")),
        "wi_gate": spec((E, d, ff), ("experts", "embed", "mlp"),
                        fan_in_axes=(1,)),
        "wi_up": spec((E, d, ff), ("experts", "embed", "mlp"),
                      fan_in_axes=(1,)),
        "wo": spec((E, ff, d), ("experts", "mlp", "embed"),
                   fan_in_axes=(1,)),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_spec(d, ff * cfg.n_shared_experts)
    return s


class MoEStats(NamedTuple):
    aux_loss: jnp.ndarray        # load-balance loss (scalar)
    expert_counts: jnp.ndarray   # (E,) tokens routed per expert
    dropped: jnp.ndarray         # overflow-dropped assignments (scalar)


def capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(math.ceil(tokens * top_k * cf / n_experts))
    return max(8, ((c + 7) // 8) * 8)     # pad for TPU-friendly tiling


def suggest_capacity(count_ema: jnp.ndarray, top_k: int,
                     slack: float = 1.2) -> int:
    """§4.6.1 dynamic batch size, adapted: next-step capacity from the
    observed per-expert load EMA (host-side; shapes are static per step)."""
    return int(float(count_ema.max()) * slack) + 8


def moe(p, x, cfg, cap: int | None = None):
    """x: (B, S, d) -> (out (B, S, d), MoEStats)."""
    from repro.distributed.sharding import annotate
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    ds = cfg.moe_data_shards
    if ds <= 1 or (B * S) % ds:
        ds = 1
    T = (B * S) // ds                                  # tokens per shard
    C = cap or capacity(T, k, E, cfg.capacity_factor)

    xt = annotate(x.reshape(ds, T, d), "batch", None, None)
    logits = jnp.einsum("xtd,de->xte", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)              # (ds, T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- conflict-group formation (stable sort = dependency order) ----
    eflat = eidx.reshape(ds, T * k).astype(jnp.int32)
    gflat = gates.reshape(ds, T * k)
    order = jnp.argsort(eflat, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(eflat, order, axis=-1)
    is_leader = jnp.concatenate(
        [jnp.ones((ds, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]],
        axis=-1)
    idx = jnp.arange(T * k, dtype=jnp.int32)[None]
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_leader, idx, 0), axis=-1)
    rank = idx - run_start                             # position in group
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)  # overflow -> drop

    # ---- gather into the per-shard (E, C) capacity grid ----
    sid = jnp.arange(ds, dtype=jnp.int32)[:, None]
    token_of = (order // k).astype(jnp.int32)
    slot_token = jnp.full((ds, E * C), T, jnp.int32).at[
        sid, dest].set(token_of, mode="drop")
    slot_gate = jnp.zeros((ds, E * C), jnp.float32).at[
        sid, dest].set(jnp.take_along_axis(gflat, order, -1), mode="drop")
    xt_pad = jnp.concatenate([xt, jnp.zeros((ds, 1, d), xt.dtype)], axis=1)
    h = jnp.take_along_axis(xt_pad, slot_token[..., None], axis=1)
    h = annotate(h, "batch", "model", None)       # (ds, E*C, d) pre-grid
    h = annotate(h.reshape(ds, E, C, d), "batch", "model", None, None)

    # ---- one dense matmul per group (EP over the expert axis) ----
    act = jax.nn.silu(jnp.einsum("xecd,edf->xecf", h,
                                 p["wi_gate"].astype(x.dtype)))
    up = jnp.einsum("xecd,edf->xecf", h, p["wi_up"].astype(x.dtype))
    oe = jnp.einsum("xecf,efd->xecd", act * up, p["wo"].astype(x.dtype))
    oe = annotate(oe, "batch", "model", None, None)

    # ---- combine (one weighted scatter per group member) ----
    contrib = (oe.reshape(ds, E * C, d).astype(jnp.float32)
               * slot_gate[..., None])
    contrib = annotate(contrib, "batch", "model", None)
    y0 = annotate(jnp.zeros((ds, T + 1, d), jnp.float32),
                  "batch", None, None)
    # vmapped scatter: the shard dim becomes a scatter *batch* dim, which
    # SPMD partitions (explicit leading indices would force replication)
    y = jax.vmap(lambda yy, idx, cc_: yy.at[idx].add(cc_))(
        y0, slot_token, contrib)[:, :T]
    y = annotate(y, "batch", None, None).astype(x.dtype)

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], xt)

    # load-balance aux loss (Switch/GShard form), fleet-wide
    cnt = jnp.zeros((ds, E), jnp.float32).at[sid, eflat].add(1.0).sum(0)
    frac_tokens = cnt / jnp.maximum(cnt.sum(), 1.0)
    frac_prob = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_prob)
    stats = MoEStats(aux_loss=aux, expert_counts=cnt.astype(jnp.int32),
                     dropped=jnp.sum(~keep).astype(jnp.int32))
    return y.reshape(B, S, d), stats
