"""Model substrate: functional layers, mixers, and LM assembly."""
from .common import (ParamSpec, spec, init_params, abstract_params,
                     param_axes, stack_specs, count_params, is_spec,
                     tree_map_specs)
from .lm import lm_spec, forward, loss_fn, prefill, decode_step, LMOutput
from .transformer import (lm_cache_shapes, lm_init_cache, block_spec,
                          block_apply)

__all__ = [
    "ParamSpec", "spec", "init_params", "abstract_params", "param_axes",
    "stack_specs", "count_params", "is_spec", "tree_map_specs",
    "lm_spec", "forward", "loss_fn", "prefill", "decode_step", "LMOutput",
    "lm_cache_shapes", "lm_init_cache", "block_spec", "block_apply",
]
