"""Parameter-spec system: one tree of ``ParamSpec`` drives initialization,
abstract (dry-run) instantiation, and sharding resolution.

Logical axis names used across the framework (resolved to mesh axes by
``repro.distributed.sharding``):

  "embed"    model width (d_model)
  "heads"    flattened attention head dim (n_heads * head_dim)
  "kv"       flattened kv head dim
  "mlp"      FFN hidden
  "vocab"    vocabulary rows
  "experts"  MoE expert axis
  "lru"      RG-LRU width / SSD inner channels
  "state"    SSM state / MLA latent
  None       never sharded (biases, norms, small vectors)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "dense"      # dense | embed | zeros | ones | value
    value: float = 0.0       # for init == "value"
    fan_in_axes: Tuple[int, ...] = (0,)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, init="dense", value=0.0, fan_in_axes=(0,)) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, value,
                     tuple(fan_in_axes))


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(f: Callable[[ParamSpec], Any], specs):
    return jax.tree.map(f, specs, is_leaf=is_spec)


def _init_one(s: ParamSpec, key, dtype) -> jnp.ndarray:
    if s.init == "zeros":
        return jnp.zeros(s.shape, dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, dtype)
    if s.init == "value":
        return jnp.full(s.shape, s.value, dtype)
    fan_in = max(int(np.prod([s.shape[a] for a in s.fan_in_axes])), 1)
    scale = 1.0 if s.init == "embed" else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, s.shape, jnp.float32)
            * scale).astype(dtype)


def init_params(specs, key, dtype=jnp.float32):
    """Materialize a spec tree into concrete parameters."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs, dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs)


def param_axes(specs):
    """Tree of logical-axis tuples, parallel to the param tree."""
    return tree_map_specs(lambda s: s.axes, specs)


def stack_specs(specs, n: int, axis_name: Optional[str] = None):
    """Stack a spec tree along a new leading axis (scanned layer groups)."""
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                            s.value, tuple(a + 1 for a in s.fan_in_axes)),
        specs)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))
