"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm (the paper's Listing 1, adapted to JAX):
the sequence is split into chunks of length Q; within a chunk the output is
an attention-like quadratic form masked by the decay kernel; across chunks
a linear recurrence carries the (H, P, N) state. All matmuls are dense and
MXU-shaped. Decode is the pure recurrence.

Shapes: d_inner = expand * d_model; H = d_inner / head_dim (P = head_dim);
N = ssm_state. B and C projections are shared across heads (n_groups = 1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import spec


def ssd_spec(cfg):
    d = cfg.d_model
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.conv_width
    return {
        "w_in": spec((d, 2 * di + 2 * N + H), ("embed", "lru")),
        "conv": spec((cw, di + 2 * N), (None, "lru")),
        "a_log": spec((H,), (None,), init="value", value=0.0),
        "dt_bias": spec((H,), (None,), init="zeros"),
        "d_skip": spec((H,), (None,), init="ones"),
        "norm": spec((di,), ("lru",), init="ones"),
        "w_out": spec((di, d), ("lru", "embed")),
    }


class SSDState(NamedTuple):
    h: jnp.ndarray        # (B, H, P, N) ssm state
    conv: jnp.ndarray     # (B, conv_width-1, d_inner + 2N)


def _split_proj(p, x, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z_x_b_c_dt = jnp.einsum("bsd,dk->bsk", x, p["w_in"].astype(x.dtype))
    z = z_x_b_c_dt[..., :di]
    xbc = z_x_b_c_dt[..., di:2 * di + 2 * N]
    dt = z_x_b_c_dt[..., 2 * di + 2 * N:]
    return z, xbc, dt


def _conv1d(p, u, state=None):
    cw = p["conv"].shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * p["conv"][i].astype(u.dtype)
              for i in range(cw))
    tail = full[:, -(cw - 1):] if cw > 1 else pad
    return jax.nn.silu(out), tail


def _segsum(a):
    """a: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums:
    out[i, j] = sum(a[j+1 .. i]) for j < i."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd(p, x, cfg, mode: str, state: SSDState | None = None):
    """x: (B, S, d) -> (out, new_state|None)."""
    B, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(p, x, cfg)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))         # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)

    if mode in ("train", "prefill"):
        from repro.distributed.sharding import annotate
        xbc, conv_tail = _conv1d(p, xbc)
        xs = annotate(xbc[..., :di].reshape(B, S, H, P),
                      "batch", None, "model", None)
        Bm = xbc[..., di:di + N]                          # (B,S,N)
        Cm = xbc[..., di + N:]                            # (B,S,N)

        Q = min(cfg.ssm_chunk, S)
        nc = S // Q
        assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
        xc = xs.reshape(B, nc, Q, H, P)
        bc = Bm.reshape(B, nc, Q, N)
        cc = Cm.reshape(B, nc, Q, N)
        dtc = dt.reshape(B, nc, Q, H)
        da = dtc * A                                      # (B,nc,Q,H)

        # 1. intra-chunk (attention-like with decay kernel). The
        # contraction order is forced explicitly — a single 4-operand
        # einsum lets XLA materialize 6-D outer products (16 GiB/device
        # at full config).
        from repro.distributed.sharding import annotate
        L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))    # (B,nc,H,Q,Q)
        L = annotate(L, "batch", None, "model", None, None)
        scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)    # (B,nc,Q,Q)
        w = scores[:, :, None].astype(jnp.float32) * L    # (B,nc,H,Q,Q)
        xdt = (xc.astype(jnp.float32)
               * dtc.astype(jnp.float32)[..., None])      # (B,nc,Q,H,P)
        y_diag = jnp.einsum("bchqk,bckhp->bcqhp", w, xdt)
        y_diag = annotate(y_diag, "batch", None, None, "model", None)

        # 2. per-chunk end states
        dec_end = jnp.exp(da.sum(axis=2, keepdims=True)
                          - jnp.cumsum(da, axis=2))       # decay to chunk end
        states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                            bc.astype(jnp.float32),
                            (dtc * dec_end).astype(jnp.float32),
                            xc.astype(jnp.float32))       # (B,nc,H,P,N)
        states = annotate(states, "batch", None, "model", None, None)

        # 3. inter-chunk recurrence over chunk states
        chunk_decay = jnp.exp(da.sum(axis=2))             # (B,nc,H)

        def scan_fn(h, inp):
            st, dec = inp
            h = h * dec[..., None, None] + st
            return h, h
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
        _, hs = jax.lax.scan(
            scan_fn, h0,
            (states.transpose(1, 0, 2, 3, 4),
             chunk_decay.transpose(1, 0, 2)))
        hs = hs.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,P,N)
        h_prev = jnp.concatenate([h0[:, None], hs[:, :-1]], axis=1)

        # 4. inter-chunk contribution: h_prev reaches step t decayed by the
        # *inclusive* prefix exp(sum_{j<=t} da_j)
        dec_in = jnp.exp(jnp.cumsum(da, axis=2))
        y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                           cc.astype(jnp.float32),
                           dec_in.astype(jnp.float32), h_prev)

        y = annotate((y_diag + y_off).reshape(B, S, H, P),
                     "batch", None, "model", None)
        y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
            * xs.astype(jnp.float32)
        y = y.reshape(B, S, di)
        # gated RMSNorm (mamba2's norm-before-out)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        var = jnp.mean(jnp.square(y), -1, keepdims=True)
        y = y * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(jnp.float32)
        out = jnp.einsum("bsk,kd->bsd", y.astype(x.dtype),
                         p["w_out"].astype(x.dtype))
        new_state = None
        if mode == "prefill":
            new_state = SSDState(h=hs[:, -1], conv=conv_tail.astype(
                jnp.float32))
        return out, new_state

    # ------------------------------------------------------------ decode
    assert state is not None
    xbc, conv_tail = _conv1d(p, xbc, state.conv)
    xs = xbc[..., :di].reshape(B, H, P)                   # S == 1 squeezed
    Bm = xbc[:, 0, di:di + N]                             # (B,N)
    Cm = xbc[:, 0, di + N:]
    dt1 = dt[:, 0]                                        # (B,H)
    decay = jnp.exp(dt1 * A)                              # (B,H)
    dbx = jnp.einsum("bn,bh,bhp->bhpn", Bm.astype(jnp.float32),
                     dt1, xs.astype(jnp.float32))
    h = state.h * decay[..., None, None] + dbx
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(B, di)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    var = jnp.mean(jnp.square(y), -1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(jnp.float32)
    out = jnp.einsum("bk,kd->bd", y.astype(x.dtype),
                     p["w_out"].astype(x.dtype))
    return out[:, None], SSDState(h=h, conv=conv_tail.astype(jnp.float32))
