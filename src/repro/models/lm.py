"""Full language model: embed -> layer groups -> head; train/prefill/decode.

Handles the modality stubs: ``cfg.embed_inputs=False`` architectures
(musicgen, qwen2-vl) take precomputed frame/patch embeddings instead of
token ids; musicgen emits ``n_codebooks`` parallel heads.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import spec
from .layers import (embed_spec, embed, unembed_spec, unembed,
                     rmsnorm_spec, rmsnorm)
from .transformer import lm_block_specs, group_apply_layers


def lm_spec(cfg):
    s = {}
    if cfg.embed_inputs:
        s["embed"] = embed_spec(cfg.padded_vocab, cfg.d_model)
    s["blocks"] = lm_block_specs(cfg)
    s["ln_f"] = rmsnorm_spec(cfg.d_model)
    s["head"] = unembed_spec(cfg.d_model, cfg.padded_vocab,
                             max(cfg.n_codebooks, 1))
    return s


class LMOutput(NamedTuple):
    logits: jnp.ndarray
    caches: Any
    aux_loss: jnp.ndarray


def forward(params, cfg, tokens=None, embeds=None, mode="train",
            caches=None, pos=None, positions3=None,
            use_kernel=False, max_len=None) -> LMOutput:
    from repro.distributed.sharding import annotate
    act_dtype = jnp.dtype(cfg.act_dtype)
    if cfg.embed_inputs:
        x = embed(params["embed"], tokens).astype(act_dtype)
    else:
        x = embeds.astype(act_dtype)
    x = annotate(x, "batch", "model", None)   # sequence-parallel residual

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    for gi, (unit, reps) in enumerate(cfg.layout):
        gkey = f"g{gi}"
        gcache = caches[gkey] if caches is not None else None
        x, nc, aux = group_apply_layers(
            params["blocks"][gkey], x, cfg, unit, mode, caches=gcache,
            pos=pos, positions3=positions3, use_kernel=use_kernel,
            remat=cfg.remat, max_len=max_len)
        new_caches[gkey] = nc
        aux_total = aux_total + aux

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if mode == "prefill":
        x = x[:, -1:]          # only the last position feeds decoding
    if mode == "train" and cfg.loss_chunk:
        # chunked-CE path: hand hidden states to the loss (logits are
        # materialized chunk-by-chunk there)
        return LMOutput(logits=x, caches=None, aux_loss=aux_total)
    logits = unembed(params["head"], x)
    logits = annotate(logits, *(("batch",) + (None,) * (logits.ndim - 2)
                                + ("model",)))
    return LMOutput(logits=logits,
                    caches=new_caches if mode != "train" else None,
                    aux_loss=aux_total)


def _ce_sums(logits, labels, vocab: int, zloss: float = 0.0):
    """Masked-sum CE. logits: (..., V_padded); labels: (...) int32."""
    V = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    if V > vocab:
        pad_mask = jnp.arange(V) < vocab
        lg = jnp.where(pad_mask, lg, -1e30)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(
        lg, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if zloss:
        nll = nll + zloss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask), jnp.sum(mask)


def cross_entropy(logits, labels, vocab: int, zloss: float = 0.0):
    tot, n = _ce_sums(logits, labels, vocab, zloss)
    return tot / jnp.maximum(n, 1.0)


def chunked_cross_entropy(head_params, x, labels, cfg):
    """Sequence-chunked CE: logits exist only one chunk at a time (the
    (B, S, V) tensor is never materialized — essential for 256k vocabs at
    1M-token steps)."""
    from .layers import unembed as _unembed
    from repro.distributed.sharding import annotate
    B, S, d = x.shape
    c = cfg.loss_chunk
    assert S % c == 0, (S, c)
    nc = S // c
    xs = x.reshape(B, nc, c, d).swapaxes(0, 1)          # (nc, B, c, d)
    if labels.ndim == 2:
        ls = labels.reshape(B, nc, c).swapaxes(0, 1)
    else:
        K = labels.shape[-1]
        ls = labels.reshape(B, nc, c, K).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xl):
        xc, lc = xl
        logits = _unembed(head_params, xc)
        logits = annotate(logits, *(("batch",)
                                    + (None,) * (logits.ndim - 2)
                                    + ("model",)))
        nll, cnt = _ce_sums(logits, lc, cfg.vocab, cfg.zloss)
        tot, n = carry
        return (tot + nll, n + cnt), None

    (tot, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls))
    return tot / jnp.maximum(n, 1.0)


def loss_fn(params, cfg, batch, use_kernel=False):
    """batch: dict with 'tokens'/'embeds', 'labels', optional 'positions3'.

    Returns (loss, dict of metrics).
    """
    out = forward(params, cfg,
                  tokens=batch.get("tokens"),
                  embeds=batch.get("embeds"),
                  positions3=batch.get("positions3"),
                  mode="train", use_kernel=use_kernel)
    if cfg.loss_chunk:
        ce = chunked_cross_entropy(params["head"], out.logits,
                                   batch["labels"], cfg)
    else:
        ce = cross_entropy(out.logits, batch["labels"], cfg.vocab,
                           cfg.zloss)
    loss = ce + 0.01 * out.aux_loss
    return loss, {"ce": ce, "aux": out.aux_loss}


def prefill(params, cfg, tokens=None, embeds=None, positions3=None,
            use_kernel=False, max_len=None):
    """Build caches from a prompt; returns (last-token logits, caches).

    ``max_len`` preallocates cache capacity for subsequent decode steps.
    """
    out = forward(params, cfg, tokens=tokens, embeds=embeds,
                  positions3=positions3, mode="prefill",
                  use_kernel=use_kernel, max_len=max_len)
    return out.logits[:, -1:], out.caches


def decode_step(params, cfg, tokens=None, embeds=None, caches=None,
                pos=None, positions3=None):
    """One decode step. tokens: (B, 1). Returns (logits, new caches)."""
    out = forward(params, cfg, tokens=tokens, embeds=embeds, caches=caches,
                  pos=pos, positions3=positions3, mode="decode")
    return out.logits, out.caches
