"""Fault tolerance: failure detection + elastic re-mesh planning.

On a real cluster this runs against the coordination service; here the
*planning* layer is implemented and unit-tested (the decisions are pure
functions), and the container-scale integration test exercises
checkpoint -> kill -> restore -> reshard end-to-end on CPU devices.

Recovery protocol (mirrors §5.3 failure recovery):
  1. heartbeat loss > ``timeout`` marks a host failed,
  2. surviving hosts agree on the new device set (the journal's latest
     committed step is the restore point — commit order is total),
  3. ``elastic_mesh_shape`` picks the largest mesh preserving the model
     axis; ``reshard_plan`` maps old shards to new hosts,
  4. every host restores from the checkpoint with the *new* shardings
     (restore is sharding-agnostic) and training resumes at step k+1 —
    the data pipeline is a pure function of step, so no data is lost or
    replayed out of order.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 30.0
    _last: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host_id: int, now: Optional[float] = None):
        self._last[host_id] = time.monotonic() if now is None else now

    def failed(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def alive(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t <= self.timeout_s)


def reshard_plan(old_hosts: List[int], new_hosts: List[int],
                 n_shards: int) -> Dict[int, List[int]]:
    """Assign shard ranges to surviving hosts (contiguous, balanced)."""
    assert new_hosts, "no survivors"
    per = n_shards // len(new_hosts)
    extra = n_shards % len(new_hosts)
    plan: Dict[int, List[int]] = {}
    start = 0
    for i, h in enumerate(new_hosts):
        k = per + (1 if i < extra else 0)
        plan[h] = list(range(start, start + k))
        start += k
    return plan


@dataclasses.dataclass
class RecoveryDecision:
    restore_step: Optional[int]
    mesh_shape: tuple
    mesh_axes: tuple
    shard_plan: Dict[int, List[int]]


def plan_recovery(monitor: HeartbeatMonitor, journal,
                  devices_per_host: int, model_axis: int = 16,
                  now: Optional[float] = None) -> RecoveryDecision:
    from repro.launch.mesh import elastic_mesh_shape
    alive = monitor.alive(now)
    n_dev = len(alive) * devices_per_host
    shape, axes = elastic_mesh_shape(max(n_dev, 1), model_axis)
    return RecoveryDecision(
        restore_step=journal.latest_committed(),
        mesh_shape=shape,
        mesh_axes=axes,
        shard_plan=reshard_plan(alive, alive, shape[0]),
    )
