from .sharding import (RULES, ResolveReport, resolve_spec, param_shardings,
                       param_pspecs, batch_pspec, batch_shardings,
                       cache_shardings, data_axes, scalar_sharding)
from .fault import (HeartbeatMonitor, reshard_plan, plan_recovery,
                    RecoveryDecision)
from .straggler import StragglerDetector, rebalance

__all__ = [
    "RULES", "ResolveReport", "resolve_spec", "param_shardings",
    "param_pspecs", "batch_pspec", "batch_shardings", "cache_shardings",
    "data_axes", "scalar_sharding",
    "HeartbeatMonitor", "reshard_plan", "plan_recovery", "RecoveryDecision",
    "StragglerDetector", "rebalance",
]
