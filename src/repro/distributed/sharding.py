"""Logical-axis sharding rule resolver.

Parameters carry logical axis names (see models/common.py). The resolver
maps each logical axis to mesh axes according to an ordered candidate list,
enforcing (a) divisibility of the dimension by the mesh-axis product and
(b) no mesh axis consumed twice within one tensor. Fallback is replication
— every fallback is recorded so the dry-run can report degraded shardings.

Rule sets:
  * ``train``: FSDP+TP — width axes shard over "model" (TP); depth axes
    ("embed", "vocab") also shard over "data" (+"pod"), fully sharding
    parameters and optimizer state (ZeRO-3 semantics via XLA all-gathers).
  * ``serve``: TP only — weights replicated over "data" (batch axis),
    sharded over "model"; no per-step all-gathers of weights.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import is_spec, tree_map_specs
from repro.models.attention import KVCache, MLACache
from repro.models.rglru import RGLRUState
from repro.models.ssd import SSDState

# logical axis -> ordered candidates (each candidate = tuple of mesh axes)
RULES = {
    "train": {
        "embed": (("data",), ()),
        "mlp": (("model",), ()),
        "heads": (("model",), ()),
        "kv": (("model",), ()),
        "vocab": (("data", "model"), ("model",), ("data",), ()),
        "experts": (("model",), ()),
        "lru": (("model",), ()),
        "state": (("model",), ()),
        "layers": ((),),
    },
    # pure data-parallel training (replicated params): for sub-1B models
    # the FSDP all-gathers cost more than they save — grads all-reduce
    # once instead (hillclimb H1 on the collective-bound cells).
    "train_dp": {
        "embed": ((),),
        "mlp": (("model",), ()),
        "heads": (("model",), ()),
        "kv": (("model",), ()),
        "vocab": (("model",), ()),
        "experts": (("model",), ()),
        "lru": (("model",), ()),
        "state": (("model",), ()),
        "layers": ((),),
    },
    "serve": {
        "embed": ((),),
        # second candidate: when "model" is consumed (expert axis), spread
        # the ff dim over "data" — this is what fits arctic-480b weights
        # (960 GB bf16) on a 256-chip pod at serve time.
        "mlp": (("model",), ("data",), ()),
        "heads": (("model",), ()),
        "kv": (("model",), ()),
        "vocab": (("model",), ()),
        "experts": (("model",), ()),
        "lru": (("model",), ()),
        "state": (("model",), ()),
        "layers": ((),),
    },
}


@dataclasses.dataclass
class ResolveReport:
    fallbacks: list = dataclasses.field(default_factory=list)

    def note(self, shape, axes, axis, wanted):
        self.fallbacks.append((tuple(shape), tuple(axes), axis, wanted))


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[n] for n in names])) if names else 1


def resolve_spec(shape, axes, mesh: Mesh, rules,
                 report: Optional[ResolveReport] = None) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec."""
    used: set = set()
    out = []
    for dim, ax in zip(shape, axes):
        placed = None
        if ax is not None and ax in rules:
            for cand in rules[ax]:
                cand = tuple(c for c in cand if c in mesh.shape)
                if any(c in used for c in cand):
                    continue
                if cand and dim % _axis_size(mesh, cand) == 0:
                    placed = cand
                    break
                if not cand:
                    placed = ()
                    break
            if placed is None:
                placed = ()
            if placed == () and rules[ax][0] != () and report is not None:
                report.note(shape, axes, ax, rules[ax][0])
        out.append(placed if placed else None)
        if placed:
            used.update(placed)
    # collapse single-axis tuples for readability
    out = [o[0] if (isinstance(o, tuple) and len(o) == 1) else o for o in out]
    return P(*out)


def param_shardings(specs, mesh: Mesh, mode: str = "train",
                    report: Optional[ResolveReport] = None):
    """NamedSharding tree for a ParamSpec tree."""
    rules = RULES[mode]

    def f(s):
        return NamedSharding(mesh, resolve_spec(s.shape, s.axes, mesh,
                                                rules, report))
    return tree_map_specs(f, specs)


def param_pspecs(specs, mesh: Mesh, mode: str = "train"):
    rules = RULES[mode]
    return tree_map_specs(
        lambda s: resolve_spec(s.shape, s.axes, mesh, rules), specs)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All data-parallel mesh axes ("pod" included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_pspec(mesh: Mesh, ndim: int, batch_dim: int = 0) -> P:
    spec = [None] * ndim
    spec[batch_dim] = data_axes(mesh)
    return P(*spec)


def batch_shardings(tree, mesh: Mesh, batch_dims=None):
    """Shard the batch dim of every array-like leaf over the data axes.

    ``batch_dims``: optional dict key->dim for dict trees whose batch axis
    is not 0 (e.g. "positions3" with shape (3, B, S) has batch dim 1).
    """
    batch_dims = batch_dims or {}

    def f(path, leaf):
        bd = 0
        for entry in path:
            key = getattr(entry, "key", None)
            if key in batch_dims:
                bd = batch_dims[key]
        da = data_axes(mesh)
        if leaf.shape[bd] % max(_axis_size(mesh, da), 1):
            return NamedSharding(mesh, P())          # tiny batch: replicate
        return NamedSharding(mesh, batch_pspec(mesh, len(leaf.shape), bd))
    return jax.tree_util.tree_map_with_path(f, tree)


# candidate "model"-axis dims per cache leaf (stacked layout with leading
# layers axis), in preference order. head_dim / latent dims are never
# sharded (they are contracting dims of attention).
_CACHE_PREF = {
    "k": (3, 2),      # (L, B, S, K, D): kv heads, else sequence
    "v": (3, 2),
    "ckv": (2,),      # (L, B, S, R): sequence only (latent contracts)
    "krope": (),      # tiny; replicate
    "h": (2,),        # rglru (L,B,W) width / ssd (L,B,H,P,N) heads
    "conv": (3,),     # (L, B, cw-1, C): channels
}


def _cache_leaf_pspec(mesh: Mesh, name: str, leaf_shape, stacked: bool) -> P:
    da = data_axes(mesh)
    dsz = _axis_size(mesh, da)
    msz = mesh.shape.get("model", 1)
    nd = len(leaf_shape)
    lead = 1 if stacked else 0          # batch axis position
    spec: list = [None] * nd
    if leaf_shape[lead] % max(dsz, 1) == 0 and dsz > 1:
        spec[lead] = da                  # batch axis (replicate if B==1)
    for c in _CACHE_PREF.get(name, ()):
        i = c if stacked else c - 1
        if i <= lead or i >= nd:
            continue
        if leaf_shape[i] % msz == 0 and leaf_shape[i] >= msz:
            spec[i] = "model"
            break
    return P(*spec)


def cache_shardings(cache_tree, mesh: Mesh, stacked: bool = True):
    def f(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "name", None) or getattr(entry, "key", None)
            if key is not None:
                name = str(key)
                break
        return NamedSharding(
            mesh, _cache_leaf_pspec(mesh, name, leaf.shape, stacked))
    return jax.tree_util.tree_map_with_path(f, cache_tree)


def scalar_sharding(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# activation annotations (set by launchers; no-op without a mesh)
# ---------------------------------------------------------------------------

_ACT_MESH: list = [None]


def set_activation_mesh(mesh: Optional[Mesh]):
    """Launchers set this so model code can annotate activations. Model
    code stays mesh-agnostic; tests on 1 device leave it unset (no-op)."""
    _ACT_MESH[0] = mesh


def annotate(x, *dims):
    """Constrain activation sharding. dims: "batch" | "model" | None per
    axis. No-op unless a launcher installed a mesh (and the dim divides).
    """
    mesh = _ACT_MESH[0]
    if mesh is None:
        return x
    spec = []
    for d, size in zip(dims, x.shape):
        if d == "batch":
            da = data_axes(mesh)
            ok = da and size % _axis_size(mesh, da) == 0
            spec.append(da if ok else None)
        elif d == "model":
            ok = "model" in mesh.shape and size % mesh.shape["model"] == 0
            spec.append("model" if ok else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
