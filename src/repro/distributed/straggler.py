"""Straggler mitigation: detection + deterministic work rebalancing.

In synchronous SPMD training a slow host delays every step (the collective
is the barrier). Mitigations implemented at the planning layer:

  * detection: per-host step-time EWMA; a host is a straggler when its
    EWMA exceeds ``threshold`` x the fleet median,
  * mitigation 1 (rebalance): move a fraction of the straggler's data
    shards to the fastest hosts (deterministic plan; the data pipeline is
    keyed by (host, shard, step) so reassignment is exact),
  * mitigation 2 (eject): persistent stragglers are treated as failed and
    handed to the fault path (elastic re-mesh).

The XLA-level knobs that pair with this (documented for real-TPU runs):
``--xla_tpu_enable_latency_hiding_scheduler=true`` overlaps the gradient
all-reduce with the backward pass, which hides moderate skew entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.2
    threshold: float = 1.5
    eject_after: int = 5
    _ewma: Dict[int, float] = dataclasses.field(default_factory=dict)
    _strikes: Dict[int, int] = dataclasses.field(default_factory=dict)

    def observe(self, host_id: int, step_time_s: float):
        prev = self._ewma.get(host_id, step_time_s)
        self._ewma[host_id] = (1 - self.alpha) * prev \
            + self.alpha * step_time_s

    def median(self) -> float:
        vals = sorted(self._ewma.values())
        return vals[len(vals) // 2] if vals else 0.0

    def stragglers(self) -> List[int]:
        med = self.median()
        out = []
        for h, t in self._ewma.items():
            if med > 0 and t > self.threshold * med:
                self._strikes[h] = self._strikes.get(h, 0) + 1
                out.append(h)
            else:
                self._strikes[h] = 0
        return sorted(out)

    def ejections(self) -> List[int]:
        return sorted(h for h, s in self._strikes.items()
                      if s >= self.eject_after)


def rebalance(shard_map_: Dict[int, List[int]], straggler: int,
              fraction: float = 0.5) -> Dict[int, List[int]]:
    """Move `fraction` of a straggler's shards to the least-loaded hosts."""
    plan = {h: list(s) for h, s in shard_map_.items()}
    if straggler not in plan or not plan[straggler]:
        return plan
    n_move = max(1, int(len(plan[straggler]) * fraction))
    moving = plan[straggler][-n_move:]
    plan[straggler] = plan[straggler][:-n_move]
    targets = sorted((h for h in plan if h != straggler),
                     key=lambda h: len(plan[h]))
    for i, s in enumerate(moving):
        plan[targets[i % len(targets)]].append(s)
    return plan
