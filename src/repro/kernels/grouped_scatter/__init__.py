from .kernel import segment_sums
from .ops import grouped_scatter_apply
from .ref import segment_sums_ref, grouped_apply_ref

__all__ = ["segment_sums", "grouped_scatter_apply", "segment_sums_ref",
           "grouped_apply_ref"]
