"""Jitted wrapper: hotspot-grouped scatter-apply built on the Pallas
segment-matmul kernel.

Pipeline (paper §4.1-§4.2 on tensors):
  1. detect hot ids (in-batch conflict count > threshold),
  2. cold ids -> native scatter (2PL path),
  3. hot ids -> conflict groups: stable sort, group index per row
     (``hot_update_order`` is the sort order), Pallas segment reduction,
     one scatter per distinct hot row (the leader's single write).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hotspot import batch_counts, DEFAULT_THRESHOLD
from .kernel import segment_sums


@functools.partial(jax.jit, static_argnames=("threshold", "max_hot",
                                             "interpret"))
def grouped_scatter_apply(table: jnp.ndarray, ids: jnp.ndarray,
                          updates: jnp.ndarray,
                          threshold: int = DEFAULT_THRESHOLD,
                          max_hot: int = 256,
                          interpret: bool = True) -> jnp.ndarray:
    """Apply (ids -> updates) into table rows, hot rows via the kernel.

    max_hot: static bound on distinct hot rows per batch (hot rows are by
    definition few — the paper's premise).
    """
    V, D = table.shape
    ids = ids.reshape(-1)
    updates = updates.reshape(-1, D)
    N = ids.shape[0]

    counts = batch_counts(ids, V)
    hot_row = counts > threshold                      # (V,) mask
    is_hot = hot_row[ids]                             # (N,)

    # ---- cold path: native scatter (2PL) ----
    sentinel = jnp.int32(V)
    cold_ids = jnp.where(is_hot, sentinel, ids)
    out = table.at[cold_ids].add(
        jnp.where(is_hot[:, None], 0, updates).astype(table.dtype),
        mode="drop")

    # ---- hot path: conflict groups -> Pallas segment reduce ----
    # enumerate distinct hot rows (static bound max_hot)
    hot_rows = jnp.nonzero(hot_row, size=max_hot, fill_value=V)[0]  # (H,)
    # group index of each update: position of its row in hot_rows
    gidx = jnp.searchsorted(hot_rows, ids).astype(jnp.int32)
    gvalid = is_hot & (hot_rows[jnp.clip(gidx, 0, max_hot - 1)] == ids)
    gidx = jnp.where(gvalid, gidx, -1)
    sums = segment_sums(gidx, jnp.where(gvalid[:, None], updates, 0),
                        num_groups=max_hot, interpret=interpret)
    # one write per group (leader lock/release once)
    return out.at[hot_rows].add(sums.astype(table.dtype), mode="drop")
