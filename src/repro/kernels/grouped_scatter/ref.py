"""Pure-jnp oracle for the grouped conflict-update kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sums_ref(seg_ids: jnp.ndarray, updates: jnp.ndarray,
                     num_groups: int) -> jnp.ndarray:
    """seg_ids: (N,) i32 sorted group index per row; updates: (N, D) f32.
    Returns (num_groups, D) per-group sums."""
    return jax.ops.segment_sum(updates.astype(jnp.float32), seg_ids,
                               num_segments=num_groups)


def grouped_apply_ref(table: jnp.ndarray, ids: jnp.ndarray,
                      updates: jnp.ndarray) -> jnp.ndarray:
    """End-to-end oracle: the serialized duplicate-index scatter (what the
    paper calls 2PL) — the grouped kernel must match this bit-for-bit in
    f32."""
    return table.at[ids].add(updates.astype(table.dtype), mode="drop")
