"""Pallas TPU kernel: blocked segment reduction as a one-hot MXU matmul.

Hardware adaptation (DESIGN.md §2.3): the paper's group locking turns many
conflicting row updates into one lock + a serial in-group apply. On TPU,
"serial in-group apply" maps to a *reduction*; the highest-throughput
reduction unit is the MXU, so conflict groups are folded with a blocked
one-hot matmul:

    sums[g, :] = sum_n [seg_id[n] == g] * updates[n, :]

Grid: (groups/BG, D/BD, N/BN) — the N axis is innermost ("arbitrary"
semantics) and accumulates into the (BG, BD) output block in VMEM; the
first N-step zero-initializes (classic revisited-output pattern). The
one-hot block never exists in HBM — it is synthesized in VMEM from the
(BN,) id block via an iota compare, which is exactly the VMEM-locality
rethink the kernel taxonomy prescribes for scatter/gather on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


DEF_BG = 128      # group rows per block   (MXU lane dim)
DEF_BD = 256      # feature columns per block
DEF_BN = 512      # update rows per block  (contraction dim)


def _seg_matmul_kernel(seg_ref, upd_ref, out_ref):
    g = pl.program_id(0)
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[0, :]                           # (BN,) i32 group ids
    bg = out_ref.shape[0]
    g0 = g * bg
    # synthesize the one-hot block in VMEM: (BG, BN)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bg, seg.shape[0]), 0) + g0
    onehot = (rows == seg[None, :]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(
        onehot, upd_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("num_groups", "bg", "bd", "bn",
                                    "interpret"))
def segment_sums(seg_ids: jnp.ndarray, updates: jnp.ndarray,
                 num_groups: int, bg: int = DEF_BG, bd: int = DEF_BD,
                 bn: int = DEF_BN, interpret: bool = True) -> jnp.ndarray:
    """Blocked one-hot segment sum. seg_ids: (N,) sorted (any order works —
    sortedness only improves one-hot block sparsity); updates: (N, D).

    Returns (num_groups, D) f32. Rows with seg_id outside [0, num_groups)
    are dropped.
    """
    N, D = updates.shape
    bg = min(bg, max(8, num_groups))
    bd = min(bd, D)
    bn = min(bn, N)
    G = pl.cdiv(num_groups, bg) * bg
    Np = pl.cdiv(N, bn) * bn
    Dp = pl.cdiv(D, bd) * bd
    if Np != N:
        seg_ids = jnp.pad(seg_ids, (0, Np - N), constant_values=-1)
        updates = jnp.pad(updates, ((0, Np - N), (0, 0)))
    if Dp != D:
        updates = jnp.pad(updates, ((0, 0), (0, Dp - D)))

    grid = (G // bg, Dp // bd, Np // bn)
    out = pl.pallas_call(
        _seg_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda g, d, n: (0, n)),
            pl.BlockSpec((bn, bd), lambda g, d, n: (n, d)),
        ],
        out_specs=pl.BlockSpec((bg, bd), lambda g, d, n: (g, d)),
        out_shape=jax.ShapeDtypeStruct((G, Dp), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(seg_ids[None, :], updates)
    return out[:num_groups, :D]
