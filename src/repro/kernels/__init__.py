"""Pallas TPU kernels for the perf-critical hot spots.

  grouped_scatter/   the paper's technique as a kernel: conflict-group
                     segment reduction as a blocked one-hot MXU matmul
  flash_attention/   causal online-softmax attention, GQA via index_map

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jitted wrapper), ref.py (pure-jnp oracle); validated in interpret mode
(tests/test_kernels.py shape/dtype sweeps).
"""
