from .kernel import flash_attention_bhsd
from .ops import flash_attention
from .ref import attention_ref

__all__ = ["flash_attention_bhsd", "flash_attention", "attention_ref"]
