"""Pure-jnp oracle for the flash attention kernel (GQA-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, scale: float | None = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D) with H % K == 0.
    Returns (B, Sq, H, D) in f32."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, K, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool),
                        k=k.shape[1] - Sq)
        s = jnp.where(mask[None, None, None], s, -2e38)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D)
