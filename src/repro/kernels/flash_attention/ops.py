"""Jitted wrapper: (B, S, H, D) layout adapter around the Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd, DEF_BQ, DEF_BK


@functools.partial(jax.jit, static_argnames=("causal", "scale",
                                             "interpret"))
def flash_attention(q, k, v, causal=True, scale=None, interpret=True):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D). Returns (B, Sq, H, D) f32."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    # pick block sizes that divide the (possibly small) sequence
    def pick(s, pref):
        b = min(pref, s)
        while s % b:
            b -= 1
        return b
    bq = pick(qt.shape[2], DEF_BQ)
    bk = pick(kt.shape[2], DEF_BK)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, scale=scale,
                               bq=bq, bk=bk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
