"""Pallas TPU flash attention (causal, GQA via index_map).

Classic three-scratch online-softmax formulation:
  grid = (B, H, Sq/BQ, Sk/BK), the KV axis innermost and "arbitrary";
  scratch (VMEM): running max m (BQ,1), running sum l (BQ,1), acc (BQ,D).
  Fully-masked KV blocks are skipped with pl.when (causal early-exit).
GQA never materializes repeated KV: the K/V BlockSpec index_map sends
query head h to kv head h // (H // K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEF_BQ = 256
DEF_BK = 256
NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q0 = qi * bq
    k0 = ki * bk
    live = (k0 <= q0 + bq - 1) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        if causal:
            qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "interpret"))
def flash_attention_bhsd(q, k, v, causal=True, scale=None,
                         bq: int = DEF_BQ, bk: int = DEF_BK,
                         interpret: bool = True):
    """q: (B, H, Sq, D); k/v: (B, K, Sk, D). Returns (B, H, Sq, D) f32."""
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    assert H % K == 0
    group = H // K
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    kv_blocks = Sk // bk
    grid = (B, H, Sq // bq, kv_blocks)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, kv_blocks=kv_blocks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
