"""Batched simulation-fleet subsystem (DESIGN.md §3).

Runs whole (protocol × workload × thread-count × ...) grids as single
vmapped, device-sharded JAX computations — one compile per shape bucket —
with bit-exact parity to per-config ``simulate()`` runs.

Quickstart::

    from repro.sweep import grid, run_sweep, summarize
    pts = grid(["mysql", "group"], HOT, [64, 256], horizon=200_000)
    res = run_sweep(pts)
    print("\\n".join(summarize(res)))
"""
from .grid import SweepPoint, point, grid, zip_grid, expand, PROTOCOLS_ALL
from .runner import run_sweep, summarize, SweepResults, BucketInfo
from .store import save_results, load_results, results_doc, point_record

__all__ = [
    "SweepPoint", "point", "grid", "zip_grid", "expand", "PROTOCOLS_ALL",
    "run_sweep", "summarize", "SweepResults", "BucketInfo",
    "save_results", "load_results", "results_doc", "point_record",
]
