"""JSON results store for sweep runs.

One sweep -> one JSON document: run metadata, per-bucket compile/wall
accounting, and one record per point (full config + extracted metrics).
Records are plain dicts built from the dataclasses, so downstream tooling
(benchmark trackers, plotting, PR-over-PR perf trajectories) needs no
repro imports to read them.

Schema ``repro.sweep/v2`` adds an optional per-point ``segments`` array —
the governed-run time series (one record per engine segment: window
tps/aborts, the preset the governor chose, end-of-segment contention
state). Points without a time series simply omit the key, so v2 documents
of plain sweeps are byte-compatible with v1 ones apart from the schema
tag, and :func:`load_results` reads both generations.

Compaction-scheduler runs additionally carry their accounting in the
``buckets`` records (additive ``BucketInfo`` fields, still v2):
``compacted``, ``n_repacks``, ``lane_iters`` (width x slowest-lane
iterations summed over device calls — the modeled lockstep cost), and
``repack_log`` (one ``[n_live, width, max_delta_iters]`` triple per
device call). Sort-then-cut runs write zeros / an empty log.

Schema ``repro.sweep/v3`` (obs layer, additive like v2): point
``metrics`` gain the TickBreakdown attribution (``breakdown`` /
``breakdown_hot`` tick dicts, conservation: values sum to padded-T x
elapsed ticks), and segment records gain per-window ``breakdown`` plus
end-of-segment ``wait_hist`` / ``occ_hist`` log2-bucket distribution
histograms. v1/v2 documents still load.

Schema ``repro.sweep/v4`` (hotspot attribution, additive): point
``metrics`` and segment records gain a ``hotspots`` array — the top-K
rows of the engine's per-record contention accumulator for the run /
window ({"row", "wait_ticks", "grants", "timeouts", "victims",
"queue_sum", "queue_max"} dicts, wait-descending). Empty when the run's
``EngineConfig.attrib`` is off, so v4 documents of attribution-off runs
differ from v3 only by the tag and an empty list. Conservation: the
full (untruncated) accumulator's wait_ticks sum equals
``breakdown["lock_wait"]`` exactly. v1-v3 documents still load.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any

from .runner import SweepResults

SCHEMA = "repro.sweep/v4"
SCHEMAS_READABLE = ("repro.sweep/v1", "repro.sweep/v2", "repro.sweep/v3",
                    "repro.sweep/v4")


def point_record(res: SweepResults, name: str,
                 point=None) -> dict:
    p = point or next(pt for pt in res.points if pt.name == name)
    r = res.metrics[name]
    rec = {
        "name": name,
        "protocol": p.protocol,
        "workload": dataclasses.asdict(p.workload),
        "n_threads": p.n_threads,
        "horizon": p.horizon,
        "p_abort": p.p_abort,
        "costs": dataclasses.asdict(p.costs),
        "drain": p.drain,
        "proto_over": dict(p.proto_over),
        "wall_us": res.wall_us[name],
        "metrics": dataclasses.asdict(r),
    }
    segs = res.segments.get(name)
    if segs:
        rec["segments"] = segs
    return rec


def results_doc(res: SweepResults, meta: dict | None = None) -> dict:
    return {
        "schema": SCHEMA,
        "created_unix": time.time(),
        "meta": meta or {},
        "n_points": len(res.points),
        "n_compiles": res.n_compiles,
        "wall_s": res.wall_s,
        "buckets": [dataclasses.asdict(b) for b in res.buckets],
        "points": [point_record(res, p.name, p) for p in res.points],
    }


def save_results(path: str, res: SweepResults,
                 meta: dict | None = None) -> str:
    """Write the sweep to ``path`` (dirs created); returns the path."""
    doc = results_doc(res, meta)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    os.replace(tmp, path)
    return path


def load_results(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") not in SCHEMAS_READABLE:
        raise ValueError(f"{path}: not a repro.sweep results file "
                         f"(schema {doc.get('schema')!r})")
    return doc
