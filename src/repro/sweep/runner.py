"""Batched sweep runner: one compile + one device program per shape bucket.

Points are bucketed by their compile key — ``(family, kind, n_rows)`` where
family is the tick engine or Aria — then padded to the bucket's max thread
count and txn length, stacked into an array-of-structs
(:class:`~repro.core.lock.engine.DynParams` with a leading config axis),
and executed under ``jax.vmap`` (``engine._run_batch``). Because every
protocol flag, cost constant, and workload parameter is traced, a bucket
compiles **once** no matter how many protocol / skew / thread / abort-rate
combinations it carries; chunked executions of the same bucket reuse the
executable (chunks are padded to a fixed G by replicating the last lane).

On a multi-device host the stacked config axis is sharded over the mesh's
data axes (``launch.mesh.make_host_mesh`` + ``NamedSharding``), so XLA
splits lanes across devices; on one device this is a no-op.

Per-lane results are bit-identical to running ``simulate()`` per config
(tests/test_sweep.py asserts this exactly): the vmapped ``while_loop``
select-freezes finished lanes, and padding is masked out of the engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp

from repro.core.lock import engine as _engine
from repro.core.lock import aria as _aria
from repro.core.lock.costs import protocol_params
from repro.core.lock.engine import EngineConfig
from repro.core.lock.metrics import SimResult, bench_row, extract_globals
from repro.core.lock.aria import AriaConfig, extract_aria

from .grid import SweepPoint

DEFAULT_CHUNK = 16      # lanes per device program on multi-device hosts
MIN_T_BUCKET = 64       # small configs share one padded shape


def _pow2ceil(n: int, floor: int = 1) -> int:
    v = max(int(n), floor)
    return 1 << (v - 1).bit_length()


def _est_iters(p: SweepPoint) -> float:
    """Crude engine-iteration estimate for lockstep-aware chunking.

    A vmapped while_loop steps every lane until the slowest finishes, so
    chunks should group lanes with similar iteration counts. Iterations
    track commits (~2 events per commit empirically), so the analytic
    chain model (ref_engine) is a good relative predictor; only the
    ordering matters, not the absolute value.
    """
    c = p.costs
    L = p.workload.txn_len
    if p.protocol == "aria":
        from repro.core.lock.aria import BARRIER
        bt = L * c.op_exec + BARRIER + c.commit_base + c.sync_lat
        return p.horizon / max(bt, 1)
    try:
        from repro.core.lock.ref_engine import predicted_tps
        from repro.core.lock.metrics import TICKS_PER_SEC
        chain = TICKS_PER_SEC / predicted_tps(
            p.protocol, p.n_threads, c,
            params=protocol_params(p.protocol, **p.over()))
    except Exception:
        chain = L * c.op_exec + c.commit_base + c.sync_lat
    return p.horizon / max(chain, 1)


def _make_chunks(bpts: list[SweepPoint], chunk_size: int
                 ) -> list[list[SweepPoint]]:
    """Sort by estimated iterations (desc), then cut fixed-size chunks.

    Sorting groups similar-density lanes so no chunk pairs a 3000-iteration
    lane with near-idle ones; fixed chunk sizes keep the executable count
    at one per (shape bucket, G) — exactly one when G divides the bucket.
    """
    spts = sorted(bpts, key=_est_iters, reverse=True)
    return [spts[lo:lo + chunk_size]
            for lo in range(0, len(spts), chunk_size)]


def _auto_chunk() -> int:
    """Lanes per program when the caller doesn't say.

    vmapped lanes lockstep a shared while_loop, so batching only pays when
    the hardware runs lanes in parallel (sharded over devices). On a
    single small host the measured lockstep waste exceeds the lane-level
    parallelism, so we fall back to sequential single-lane programs —
    which still amortize compiles across the whole bucket via shape
    padding (the dominant cost of a per-config loop). Multi-device widths
    are a multiple of the device count so lane sharding always divides.
    """
    n_dev = len(jax.devices())
    return max(8 * n_dev, DEFAULT_CHUNK) if n_dev > 1 else 1


@dataclasses.dataclass(frozen=True)
class BucketInfo:
    family: str             # "engine" | "aria"
    kind: str
    n_rows: int
    pad_threads: int
    pad_len: int
    n_points: int
    n_chunks: int
    wall_s: float


@dataclasses.dataclass
class SweepResults:
    """Ordered results of one sweep run.

    ``segments`` is the optional per-point time series (one JSON-ready
    record per engine segment) that governed runs (``repro.adaptive``)
    attach; plain sweeps leave it empty. The store writes it under the
    ``repro.sweep/v2`` schema.
    """
    points: list[SweepPoint]
    metrics: dict[str, SimResult]       # name -> extracted metrics
    wall_us: dict[str, float]           # name -> amortized wall per point
    buckets: list[BucketInfo]
    n_compiles: int
    wall_s: float
    segments: dict[str, list] = dataclasses.field(default_factory=dict)

    def __getitem__(self, name: str) -> SimResult:
        return self.metrics[name]

    def names(self) -> list[str]:
        return [p.name for p in self.points]


def _bucket_key(p: SweepPoint, thread_bucket) -> tuple:
    """Compile-key bucket for a point.

    ``thread_bucket="pow2"`` (default) sub-buckets by power-of-2 thread
    count (floor 64) and pads to that cap: lanes never carry more than 2x
    thread padding (a T=1 lane padded to the grid's T=1024 would step 1024
    threads every tick — the padding waste dwarfs a compile), and pad
    shapes are stable across sweeps, so later figures reuse executables.
    txn_len stays exact (per-tick op-slot work is too hot to pad; an
    L-axis sweep just gets one bucket per length).
    ``thread_bucket="max"`` forces one bucket per (family, kind, R) padded
    to the grid max — the one-compile extreme.
    """
    family = "aria" if p.protocol == "aria" else "engine"
    base = (family, p.workload.kind, p.workload.n_rows)
    if thread_bucket == "max":
        return base
    if thread_bucket == "pow2":
        return base + (_pow2ceil(p.n_threads, MIN_T_BUCKET),
                       p.workload.txn_len)
    raise ValueError(f"thread_bucket={thread_bucket!r}")


def _engine_config(p: SweepPoint) -> EngineConfig:
    return EngineConfig(
        protocol=protocol_params(p.protocol, **p.over()),
        costs=p.costs, workload=p.workload, n_threads=p.n_threads,
        horizon=p.horizon, p_abort=p.p_abort, drain=p.drain)


def _check_aria_point(p: SweepPoint) -> None:
    """Aria has no injected aborts, drain mode, or protocol knobs; reject
    rather than silently running defaults under a name that claims them."""
    unsupported = []
    if p.p_abort:
        unsupported.append(f"p_abort={p.p_abort}")
    if p.drain:
        unsupported.append("drain=True")
    if p.proto_over:
        unsupported.append(f"proto_over={dict(p.proto_over)}")
    if unsupported:
        raise ValueError(
            f"sweep point {p.name!r}: aria does not support "
            + ", ".join(unsupported))


def _stack(dps: Sequence) -> object:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *dps)


def _shard_lanes(tree, n_lanes: int):
    """Shard the leading config axis over the data axes of a host mesh.

    No-op on a single device or when the lane count doesn't divide; lanes
    always stay correct either way — this only places them.
    """
    n_dev = len(jax.devices())
    if n_dev <= 1 or n_lanes % n_dev:
        return tree
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    sh = NamedSharding(mesh, P("data"))
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def _cache_sizes() -> int:
    return (_engine._run_batch._cache_size()
            + _aria._run_batch._cache_size()
            + _engine._run_dyn._cache_size()
            + _aria._run_dyn._cache_size())


def _take(tree, i: int):
    return jax.tree.map(lambda x: x[i], tree)


def run_sweep(points: Iterable[SweepPoint], *, chunk_size: int | None = None,
              thread_bucket: str = "pow2", shard: bool = True,
              verbose: bool = False) -> SweepResults:
    """Run every point, batched per shape bucket. Order is preserved.

    ``chunk_size`` fixes the lanes per device program (vmap width); the
    default adapts to the hardware (see :func:`_auto_chunk`). Partial
    chunks are padded by replicating the last lane up to a pow2 width so
    the few (shape, G) executables get reused. ``thread_bucket`` picks the
    bucketing strategy (see :func:`_bucket_key`).
    """
    points = list(points)
    names = [p.name for p in points]
    if len(set(names)) != len(names):
        dup = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate sweep point names: {dup[:5]}")
    for p in points:            # fail fast, before any bucket burns time
        if p.protocol == "aria":
            _check_aria_point(p)
    chunk_size = chunk_size or _auto_chunk()

    buckets: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        buckets.setdefault(_bucket_key(p, thread_bucket), []).append(i)

    metrics: dict[str, SimResult] = {}
    wall_us: dict[str, float] = {}
    infos: list[BucketInfo] = []
    compiles0 = _cache_sizes()
    t_start = time.perf_counter()

    for key, idxs in buckets.items():
        family, kind, n_rows = key[:3]
        bpts = [points[i] for i in idxs]
        if len(key) > 3:        # pow2 buckets pad to the (stable) cap
            pad_t, pad_l = key[3], key[4]
        else:                   # "max": pad to the grid max
            pad_t = max(p.n_threads for p in bpts)
            pad_l = max(p.workload.txn_len for p in bpts)
        t_bucket = time.perf_counter()
        n_chunks = 0

        for chunk in _make_chunks(bpts, chunk_size):
            n_real = len(chunk)
            # pad partial chunks (replicated last lane) to a stable G so
            # the handful of (shape, G) executables get reused across
            # chunks, buckets, and figure modules: pow2 on one device,
            # a device-count multiple otherwise so lane sharding divides
            n_dev = len(jax.devices())
            if n_dev > 1 and n_real > 1:
                g = -(-n_real // n_dev) * n_dev
            else:
                g = _pow2ceil(n_real)
            chunk = chunk + [chunk[-1]] * (g - n_real)
            t0 = time.perf_counter()
            if family == "engine":
                parts = [_engine.split_config(_engine_config(p),
                                              pad_threads=pad_t,
                                              pad_len=pad_l) for p in chunk]
                stat = parts[0][0]
                if g == 1:      # share the simulate() executable
                    dp = parts[0][1]
                    out = _engine._run_dyn(stat, dp,
                                           _engine.init_state_dyn(stat, dp))
                    out = jax.tree.map(lambda x: x[None], out)
                else:
                    dps = _stack([dp for _, dp in parts])
                    s0s = _stack([_engine.init_state_dyn(stat, dp)
                                  for _, dp in parts])
                    if shard:
                        dps, s0s = _shard_lanes((dps, s0s), g)
                    out = _engine._run_batch(stat, dps, s0s)
                jax.block_until_ready(out.g.now)
            else:
                parts = [_aria.split_aria(
                    AriaConfig(p.workload, p.costs, p.n_threads, p.horizon),
                    pad_threads=pad_t, pad_len=pad_l) for p in chunk]
                stat = parts[0][0]
                if g == 1:
                    out = _aria._run_dyn(stat, parts[0][1])
                    out = jax.tree.map(lambda x: x[None], out)
                else:
                    dps = _stack([dp for _, dp in parts])
                    if shard:
                        dps = _shard_lanes(dps, g)
                    out = _aria._run_batch(stat, dps)
                jax.block_until_ready(out.now)
            # only the metrics leaves leave the device (the thread/row
            # state is G x (T,L)/(R,) arrays extract never reads)
            host = jax.device_get(out.g if family == "engine"
                                  else _aria.metrics_view(out))
            per_pt = (time.perf_counter() - t0) * 1e6 / n_real
            for j, p in enumerate(chunk[:n_real]):
                sliced = _take(host, j)
                if family == "engine":
                    metrics[p.name] = extract_globals(p.protocol,
                                                      p.n_threads, sliced)
                else:
                    metrics[p.name] = extract_aria(p.n_threads, sliced)
                wall_us[p.name] = per_pt
            n_chunks += 1

        infos.append(BucketInfo(
            family=family, kind=kind, n_rows=n_rows, pad_threads=pad_t,
            pad_len=pad_l, n_points=len(bpts), n_chunks=n_chunks,
            wall_s=time.perf_counter() - t_bucket))
        if verbose:
            b = infos[-1]
            print(f"# sweep bucket {family}/{kind}/R{n_rows}: "
                  f"{b.n_points} pts, T<={pad_t}, L<={pad_l}, "
                  f"{b.n_chunks} chunk(s), {b.wall_s:.1f}s")

    return SweepResults(
        points=points, metrics=metrics, wall_us=wall_us, buckets=infos,
        n_compiles=_cache_sizes() - compiles0,
        wall_s=time.perf_counter() - t_start)


def summarize(res: SweepResults, names: Sequence[str] | None = None
              ) -> list[str]:
    """CSV rows (``name,us_per_call,derived``) in benchmark format."""
    return [bench_row(name, res.wall_us[name], res.metrics[name])
            for name in (names if names is not None else res.names())]
