"""Batched sweep runner: one compile + one device program per shape bucket.

Points are bucketed by their compile key — ``(family, kind, n_rows)`` where
family is the tick engine or Aria — then padded to the bucket's max thread
count and txn length, stacked into an array-of-structs
(:class:`~repro.core.lock.engine.DynParams` with a leading config axis),
and executed under ``jax.vmap`` (``engine._run_batch``). Because every
protocol flag, cost constant, and workload parameter is traced, a bucket
compiles **once** no matter how many protocol / skew / thread / abort-rate
combinations it carries.

Within a bucket, vmapped execution (``chunk_size > 1``) defaults to the
**lockstep compaction scheduler** (DESIGN.md §8): lanes run in iteration
-budget slices (``dp.max_iters`` capped at ``iters + slice`` — traced, so
no recompile); between slices finished lanes retire into results
immediately, survivors are repacked into a smaller pow2-width batch, and
freed slots are topped up from the not-yet-started queue. A vmapped
``while_loop`` steps every lane until the slowest finishes, so without
compaction one 3000-iteration hotspot lane makes its G-1 chunk-mates pay
``max_iters x G``; with it the dense lane finishes in a (near-)solo pack.
``compact=False`` restores the PR-1 sort-then-cut chunking
(:func:`_make_chunks`), which is also the path taken at ``chunk_size=1``
(sequential lanes have no lockstep to compact away).

On a multi-device host the stacked config axis is sharded over the mesh's
data axes (``launch.mesh.make_host_mesh`` + ``NamedSharding``);
:func:`_shard_lanes` pads the lane axis to a device-count multiple
(replicated tail, sliced off by the caller) so placement engages for every
width.

Per-lane results are bit-identical to running ``simulate()`` per config
(tests/test_sweep.py asserts this exactly, for both execution paths): the
vmapped ``while_loop`` select-freezes finished lanes, padding is masked
out of the engine, and compaction only re-buckets *which lanes run
together* — pausing a lane at an iteration budget and resuming it replays
the identical step sequence, so even the ``iters`` diagnostic matches.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Iterable, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.lock import engine as _engine
from repro.core.lock import aria as _aria
from repro.core.lock.costs import PROTOCOLS, protocol_params
from repro.core.lock.engine import EngineConfig, I32
from repro.core.lock.metrics import (SimResult, TICKS_PER_SEC, bench_row,
                                     extract_globals)
from repro.core.lock.aria import AriaConfig, extract_aria

from .grid import SweepPoint

DEFAULT_CHUNK = 16      # lanes per device program on multi-device hosts
MIN_T_BUCKET = 64       # small configs share one padded shape
DEFAULT_SLICES = 8      # iteration-budget slices per nominal lane run

KNOWN_PROTOCOLS = PROTOCOLS + ("aria",)


def _pow2ceil(n: int, floor: int = 1) -> int:
    v = max(int(n), floor)
    return 1 << (v - 1).bit_length()


_EST_WARNED: set[str] = set()


def _est_iters(p: SweepPoint) -> float:
    """Crude engine-iteration estimate for lockstep-aware scheduling.

    A vmapped while_loop steps every lane until the slowest finishes, so
    similar-iteration lanes should run together (chunk grouping on the
    sort-then-cut path, admission order + slice sizing on the compaction
    path). Iterations track commits (~2 events per commit empirically),
    so the analytic chain model (ref_engine) is a good relative
    predictor; only the ordering and rough scale matter.
    """
    c = p.costs
    if p.protocol == "aria":
        return p.horizon / max(_aria.batch_ticks(p.workload, c), 1)
    try:
        from repro.core.lock.ref_engine import predicted_tps
        chain = TICKS_PER_SEC / predicted_tps(
            p.protocol, p.n_threads, c,
            params=protocol_params(p.protocol, **p.over()))
    except (ValueError, ZeroDivisionError) as e:
        # The analytic model not covering a (protocol, knob) combination
        # is expected — new protocols land as DynParams flags before their
        # ref model does. Anything else (KeyError from an unknown name,
        # TypeError, shape errors) is a real bug and must propagate;
        # run_sweep validates names up front so it fails loudly there.
        if p.protocol not in _EST_WARNED:
            _EST_WARNED.add(p.protocol)
            warnings.warn(
                f"_est_iters: analytic model failed for {p.protocol!r} "
                f"({e}); falling back to the cost-chain estimate "
                f"(scheduling order may degrade)", RuntimeWarning,
                stacklevel=2)
        chain = p.workload.txn_len * c.op_exec + c.commit_base + c.sync_lat
    return p.horizon / max(chain, 1)


def _make_chunks(bpts: list[SweepPoint], chunk_size: int
                 ) -> list[list[SweepPoint]]:
    """Sort by estimated iterations (desc), then cut fixed-size chunks.

    Sorting groups similar-density lanes so no chunk pairs a 3000-iteration
    lane with near-idle ones — as long as the estimate is right and the
    densities cluster; the compaction scheduler removes both assumptions.
    Fixed chunk sizes keep the executable count at one per (shape bucket,
    G) — exactly one when G divides the bucket.
    """
    spts = sorted(bpts, key=_est_iters, reverse=True)
    return [spts[lo:lo + chunk_size]
            for lo in range(0, len(spts), chunk_size)]


def _auto_chunk() -> int:
    """Lanes per program when the caller doesn't say.

    vmapped lanes lockstep a shared while_loop, so batching only pays when
    the hardware runs lanes in parallel (sharded over devices). On a
    single small host the measured lockstep waste exceeds the lane-level
    parallelism, so we fall back to sequential single-lane programs —
    which still amortize compiles across the whole bucket via shape
    padding (the dominant cost of a per-config loop). Multi-device widths
    are a multiple of the device count so lane sharding always divides.
    """
    n_dev = len(jax.devices())
    return max(8 * n_dev, DEFAULT_CHUNK) if n_dev > 1 else 1


@dataclasses.dataclass(frozen=True)
class BucketInfo:
    family: str             # "engine" | "aria"
    kind: str
    n_rows: int
    pad_threads: int
    pad_len: int
    n_points: int
    n_chunks: int           # device calls (chunks, or compaction slices)
    wall_s: float
    # --- compaction accounting (zero / empty on the sort-then-cut path) ---
    compacted: bool = False
    n_repacks: int = 0      # calls after which survivors were re-gathered
    lane_iters: int = 0     # sum over calls of width x max lane-iterations
    repack_log: tuple = ()  # per-call (n_live, width, max_delta_iters)


@dataclasses.dataclass
class SweepResults:
    """Ordered results of one sweep run.

    ``segments`` is the optional per-point time series (one JSON-ready
    record per engine segment) that governed runs (``repro.adaptive``)
    attach; plain sweeps leave it empty. The store writes it under the
    ``repro.sweep/v3`` schema.
    """
    points: list[SweepPoint]
    metrics: dict[str, SimResult]       # name -> extracted metrics
    wall_us: dict[str, float]           # name -> amortized wall per point
    buckets: list[BucketInfo]
    n_compiles: int
    wall_s: float
    segments: dict[str, list] = dataclasses.field(default_factory=dict)

    def __getitem__(self, name: str) -> SimResult:
        return self.metrics[name]

    def names(self) -> list[str]:
        return [p.name for p in self.points]

    @property
    def lane_iters(self) -> int:
        """Total vmapped lane-iterations paid (width x slowest-lane iters,
        summed over device calls) — the sweep's modeled lockstep cost."""
        return sum(b.lane_iters for b in self.buckets)

    @property
    def n_repacks(self) -> int:
        return sum(b.n_repacks for b in self.buckets)


def _bucket_key(p: SweepPoint, thread_bucket) -> tuple:
    """Compile-key bucket for a point.

    ``thread_bucket="pow2"`` (default) sub-buckets by power-of-2 thread
    count (floor 64) and pads to that cap: lanes never carry more than 2x
    thread padding (a T=1 lane padded to the grid's T=1024 would step 1024
    threads every tick — the padding waste dwarfs a compile), and pad
    shapes are stable across sweeps, so later figures reuse executables.
    txn_len stays exact (per-tick op-slot work is too hot to pad; an
    L-axis sweep just gets one bucket per length).
    ``thread_bucket="max"`` forces one bucket per (family, kind, R) padded
    to the grid max — the one-compile extreme.
    """
    family = "aria" if p.protocol == "aria" else "engine"
    base = (family, p.workload.kind, p.workload.n_rows)
    if thread_bucket == "max":
        return base
    if thread_bucket == "pow2":
        return base + (_pow2ceil(p.n_threads, MIN_T_BUCKET),
                       p.workload.txn_len)
    raise ValueError(f"thread_bucket={thread_bucket!r}")


def _engine_config(p: SweepPoint) -> EngineConfig:
    return EngineConfig(
        protocol=protocol_params(p.protocol, **p.over()),
        costs=p.costs, workload=p.workload, n_threads=p.n_threads,
        horizon=p.horizon, p_abort=p.p_abort, drain=p.drain)


def _check_aria_point(p: SweepPoint) -> None:
    """Aria has no injected aborts, drain mode, or protocol knobs; reject
    rather than silently running defaults under a name that claims them."""
    unsupported = []
    if p.p_abort:
        unsupported.append(f"p_abort={p.p_abort}")
    if p.drain:
        unsupported.append("drain=True")
    if p.proto_over:
        unsupported.append(f"proto_over={dict(p.proto_over)}")
    if unsupported:
        raise ValueError(
            f"sweep point {p.name!r}: aria does not support "
            + ", ".join(unsupported))


def _stack(dps: Sequence) -> object:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *dps)


def _pack(trees: Sequence, g: int) -> object:
    """Stack n lane pytrees to width ``g``, replicating the last lane into
    the tail pad — the pow2 widths keep the executable set bounded."""
    trees = list(trees)
    return _stack(trees + [trees[-1]] * (g - len(trees)))


def _shard_lanes(tree, n_lanes: int):
    """Shard the leading config axis over the data axes of a host mesh.

    When the lane count doesn't divide the device count, the lane axis is
    first padded to the next device-count multiple by replicating the last
    lane — so multi-device placement ALWAYS engages (12 lanes on 8 devices
    used to silently run on one). Returns ``(tree, padded_width)``; the
    caller reads only its real lanes, so the replicated tail is inert.
    No-op (width unchanged) on a single device.
    """
    n_dev = len(jax.devices())
    if n_dev <= 1:
        return tree, n_lanes
    g = -(-n_lanes // n_dev) * n_dev
    if g != n_lanes:
        pad = g - n_lanes
        tree = jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.repeat(x[-1:], pad, axis=0)]), tree)
    sh = _data_sharding(n_dev)
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree), g


_SHARDING_CACHE: dict = {}


def _data_sharding(n_dev: int):
    """Lane-axis sharding over the host mesh, built once per process —
    the compaction path shards every device call, so rebuilding the mesh
    each time would be pure overhead (the device set is fixed)."""
    if n_dev not in _SHARDING_CACHE:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_host_mesh
        _SHARDING_CACHE[n_dev] = NamedSharding(make_host_mesh(),
                                               P("data"))
    return _SHARDING_CACHE[n_dev]


def _cache_sizes() -> int:
    return (_engine._run_batch._cache_size()
            + _aria._run_batch._cache_size()
            + _engine._run_dyn._cache_size()
            + _aria._run_dyn._cache_size()
            + _aria._run_seg_dyn._cache_size()
            + _aria._run_seg_batch._cache_size())


def _take(tree, i: int):
    return jax.tree.map(lambda x: x[i], tree)


def run_packed_segment(stat, dps, states, untils, *, shard: bool = False,
                       packed=None):
    """Advance n engine lanes one segment as a single packed program.

    The shared packed-segment substrate: lanes are stacked to a pow2
    width (tail replicated via :func:`_pack`), optionally sharded over
    the host mesh, and stepped through ``engine._run_seg_batch``; a
    single lane reuses the ``_run_seg_dyn`` executable, unstacked.

    Returns ``(packed_states, packed_snaps, width)`` — lane ``i`` of
    each packed output is input lane ``i`` (slice with :func:`_take`, on
    device or after a batched ``device_get``); ``width == 1`` returns
    the bare state/snapshot. Pass ``packed_states`` back as ``packed``
    on the next segment of the SAME lane set to keep the stack resident
    on device (``states`` is only read when ``packed`` is None) — the
    governed runner (``repro.adaptive``) does this for every segment, so
    an unchanged group never pays per-lane gathers or re-stacks, exactly
    like the sweep compaction scheduler's unchanged-pack reuse.
    """
    n = len(dps)
    if n == 1:
        s0 = packed if packed is not None else states[0]
        s, snap = _engine._run_seg_dyn(stat, dps[0], s0,
                                       jnp.asarray(untils[0], I32))
        return s, snap, 1
    if packed is not None:
        s_s = packed
        g = jax.tree.leaves(s_s)[0].shape[0]
        dp_s = _pack(dps, g)
        u = jnp.asarray(list(untils) + [untils[-1]] * (g - n), I32)
        if shard:
            (dp_s, u), _ = _shard_lanes((dp_s, u), g)
    else:
        g = _pow2ceil(n)
        dp_s, s_s = _pack(dps, g), _pack(states, g)
        u = jnp.asarray(list(untils) + [untils[-1]] * (g - n), I32)
        if shard:
            (dp_s, s_s, u), g = _shard_lanes((dp_s, s_s, u), g)
    out, snaps = _engine._run_seg_batch(stat, dp_s, s_s, u)
    jax.block_until_ready(out.g.now)
    return out, snaps, g


# ---------------------------------------------------------------------------
# compaction scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Lane:
    """One point's resumable execution (host-side scheduling mirror)."""
    p: SweepPoint
    dp: object                  # DynParams | AriaDyn
    cfg: EngineConfig | None    # engine family only
    bt: int = 1                 # aria ticks per batch (loop iteration)
    state: object = None        # device SimState | AriaState once admitted
    now: int = 0
    iters: int = 0
    wall_us: float = 0.0


def _run_bucket_compact(family: str, stat, bpts: list[SweepPoint],
                        pad_t: int, pad_l: int, chunk_size: int,
                        shard: bool, slice_iters: int | None,
                        metrics: dict, wall_us: dict):
    """Run one bucket with lockstep compaction (see module docstring).

    Slices are **iteration budgets**, not sim-time windows: every lane in
    a grid typically shares the horizon, so sim-time boundaries would
    retire all lanes on the same slice and never free width. Iteration
    budgets are the resource the vmapped loop actually spends — a sparse
    lane finishes inside its first budget and retires while a dense one
    keeps paying, in an ever-narrower pack. For the engine the budget is
    the traced ``dp.max_iters`` cap (resuming replays the identical step
    sequence, so results — including ``Globals.iters`` — stay bitwise
    equal to single-shot runs); for Aria, whose every loop iteration
    advances ``now`` by exactly ``batch_ticks``, the equivalent per-lane
    pause target is ``now + slice * batch_ticks``. Only the first call's
    budget comes from the analytic estimate; subsequent budgets re-derive
    from the observed per-call progress (see the loop tail) unless
    ``slice_iters`` pins them.
    """
    queue: list[_Lane] = []
    ests = sorted(((_est_iters(p), i) for i, p in enumerate(bpts)),
                  key=lambda ei: ei[0], reverse=True)
    for _, i in ests:
        p = bpts[i]
        if family == "engine":
            cfg = _engine_config(p)
            _, dp = _engine.split_config(cfg, pad_threads=pad_t,
                                         pad_len=pad_l)
            queue.append(_Lane(p=p, dp=dp, cfg=cfg))
        else:
            _, dp = _aria.split_aria(
                AriaConfig(p.workload, p.costs, p.n_threads, p.horizon),
                pad_threads=pad_t, pad_len=pad_l)
            queue.append(_Lane(p=p, dp=dp, cfg=None,
                               bt=_aria.batch_ticks(p.workload, p.costs)))
    # Budget scale: ~1/DEFAULT_SLICES of the densest lane's estimated
    # iterations (est tracks commits ~ iters/2; the sort above puts it at
    # the head). A misestimate only changes the call count, never any
    # result — and only the FIRST call trusts the analytic estimate: from
    # then on the budget re-derives from observed execution (below)
    # unless the caller pinned it with ``slice_iters``.
    est_max = max(ests[0][0], 1.0)
    budget = slice_iters or max(256, int(2.0 * est_max / DEFAULT_SLICES))
    adaptive = slice_iters is None

    active: list[_Lane] = []
    n_calls = n_repacks = lane_iters = 0
    repack_log: list[tuple] = []
    # When a call retires nobody, the next call runs the SAME lanes in the
    # same slots — reuse the packed output states directly instead of
    # per-lane _take gathers + a fresh _stack (pure dispatch overhead on
    # the hot loop; admissions only ever follow retirements, so an
    # unchanged pack really is unchanged).
    packed = None               # (states_pytree_of_width_g_run, g_run)
    while queue or active:
        while queue and len(active) < chunk_size:
            ln = queue.pop(0)
            ln.state = (_engine.init_state_dyn(stat, ln.dp)
                        if family == "engine"
                        else _aria.init_aria_state(stat))
            active.append(ln)
        n = len(active)
        # full pools run at exactly chunk_size (a device multiple on
        # meshes — pow2ceil would overshoot a non-pow2 cap like 24);
        # the drain tail descends the pow2 width ladder below it
        g = min(_pow2ceil(n), chunk_size)
        t0 = time.perf_counter()
        phases = None
        if family == "engine":
            dps = [ln.dp._replace(max_iters=jnp.asarray(
                       min(ln.iters + budget, ln.cfg.max_iters), I32))
                   for ln in active]
            if g == 1 and packed is None:
                out = _engine._run_dyn(stat, dps[0], active[0].state)
                out = jax.tree.map(lambda x: x[None], out)
                g_run = 1
            else:
                if packed is not None:
                    s_s, g_run = packed
                    dp_s = _pack(dps, g_run)
                    if shard:
                        dp_s, _ = _shard_lanes(dp_s, g_run)
                else:
                    dp_s = _pack(dps, g)
                    s_s = _pack([ln.state for ln in active], g)
                    g_run = g
                    if shard:
                        (dp_s, s_s), g_run = _shard_lanes((dp_s, s_s), g)
                out = _engine._run_batch(stat, dp_s, s_s)
            jax.block_until_ready(out.g.now)
            host = jax.device_get(out.g)
            if any(ln.cfg.drain for ln in active):
                phases = jax.device_get(out.th.phase)
        else:
            # clamp to the horizon: the cond ANDs `now < horizon` anyway,
            # and an unclamped target can overflow i32 for large budgets
            # x batch times
            untils = [min(ln.now + budget * ln.bt, ln.p.horizon)
                      for ln in active]
            if g == 1 and packed is None:
                out = _aria._run_seg_dyn(stat, active[0].dp,
                                         active[0].state,
                                         jnp.asarray(untils[0], I32))
                out = jax.tree.map(lambda x: x[None], out)
                g_run = 1
            else:
                if packed is not None:
                    s_s, g_run = packed
                    dp_s = _pack([ln.dp for ln in active], g_run)
                    u = jnp.asarray(
                        untils + [untils[-1]] * (g_run - n), I32)
                    if shard:
                        (dp_s, u), _ = _shard_lanes((dp_s, u), g_run)
                else:
                    dp_s = _pack([ln.dp for ln in active], g)
                    s_s = _pack([ln.state for ln in active], g)
                    u = jnp.asarray(untils + [untils[-1]] * (g - n), I32)
                    g_run = g
                    if shard:
                        (dp_s, s_s, u), g_run = _shard_lanes(
                            (dp_s, s_s, u), g)
                out = _aria._run_seg_batch(stat, dp_s, s_s, u)
            jax.block_until_ready(out.now)
            host = jax.device_get(_aria.metrics_view(out))

        per_lane_us = (time.perf_counter() - t0) * 1e6 / n
        max_d = 0
        done_mask = []
        for i, ln in enumerate(active):
            h = _take(host, i)
            if family == "engine":
                delta = int(h.iters) - ln.iters
                ln.iters, ln.now = int(h.iters), int(h.now)
                done = _engine.run_finished(
                    ln.cfg, ln.now, ln.iters,
                    phase=None if phases is None else phases[i])
            else:
                delta = (int(h.now) - ln.now) // max(ln.bt, 1)
                ln.now = int(h.now)
                done = ln.now >= ln.p.horizon
            max_d = max(max_d, delta)
            ln.wall_us += per_lane_us
            if done:
                metrics[ln.p.name] = (
                    extract_globals(ln.p.protocol, ln.p.n_threads, h)
                    if family == "engine"
                    else extract_aria(ln.p.n_threads, h))
                wall_us[ln.p.name] = ln.wall_us
                ln.state = None         # free the device arrays
            done_mask.append(done)
        retired = sum(done_mask)
        if retired or g_run == 1:       # composition changes: unpack
            # (width-1 packs always unpack so solo lanes keep riding the
            # _run_dyn executable simulate() shares)
            survivors = []
            for i, ln in enumerate(active):
                if not done_mask[i]:
                    ln.state = _take(out, i)
                    survivors.append(ln)
            active = survivors
            packed = None
        else:                           # unchanged: reuse the pack as-is
            packed = (out, g_run)
        n_calls += 1
        lane_iters += g_run * max_d
        repack_log.append((n, g_run, max_d))
        if retired and active:
            n_repacks += 1
        if adaptive and active:
            # Adaptive slice budget (PR4 follow-on b): re-estimate from
            # the OBSERVED call instead of re-trusting the analytic
            # estimate. Each survivor's remaining iterations extrapolate
            # linearly in sim-time from its observed totals; the densest
            # survivor re-sets the budget at 1/DEFAULT_SLICES of its
            # projected remainder, floored at this call's max_delta_iters
            # so the budget never drops below what one call was just
            # observed to spend (shrinking only adds dispatches). A lane
            # the estimate undershot 100x now costs O(DEFAULT_SLICES)
            # extra calls, not 100 fixed-size slices; results never
            # depend on the budget (pause/resume is bit-exact).
            rem = 0.0
            for ln in active:
                if family == "engine":
                    left = max(_engine.stop_ticks(ln.cfg) - ln.now, 0)
                    rem = max(rem, ln.iters * left / max(ln.now, 1))
                else:
                    rem = max(rem, (ln.p.horizon - ln.now)
                              / max(ln.bt, 1))
            budget = max(256, max_d, int(rem / DEFAULT_SLICES))
    return n_calls, n_repacks, lane_iters, tuple(repack_log)


def _run_bucket_chunks(family: str, bpts: list[SweepPoint],
                       pad_t: int, pad_l: int, chunk_size: int,
                       shard: bool, metrics: dict, wall_us: dict):
    """The PR-1 sort-then-cut path (``compact=False`` / sequential)."""
    n_chunks = 0
    lane_iters = 0
    for chunk in _make_chunks(bpts, chunk_size):
        n_real = len(chunk)
        # pad partial chunks (replicated last lane) to a stable pow2 G
        # (capped at chunk_size, which need not be pow2) so the handful
        # of (shape, G) executables get reused across chunks, buckets,
        # and figure modules; _shard_lanes pads further to a device
        # multiple when a mesh is present
        g = min(_pow2ceil(n_real), chunk_size)
        chunk = chunk + [chunk[-1]] * (g - n_real)
        t0 = time.perf_counter()
        if family == "engine":
            parts = [_engine.split_config(_engine_config(p),
                                          pad_threads=pad_t,
                                          pad_len=pad_l) for p in chunk]
            stat = parts[0][0]
            if g == 1:      # share the simulate() executable
                dp = parts[0][1]
                out = _engine._run_dyn(stat, dp,
                                       _engine.init_state_dyn(stat, dp))
                out = jax.tree.map(lambda x: x[None], out)
                g_run = 1
            else:
                dps = _stack([dp for _, dp in parts])
                s0s = _stack([_engine.init_state_dyn(stat, dp)
                              for _, dp in parts])
                g_run = g
                if shard:
                    (dps, s0s), g_run = _shard_lanes((dps, s0s), g)
                out = _engine._run_batch(stat, dps, s0s)
            jax.block_until_ready(out.g.now)
        else:
            parts = [_aria.split_aria(
                AriaConfig(p.workload, p.costs, p.n_threads, p.horizon),
                pad_threads=pad_t, pad_len=pad_l) for p in chunk]
            stat = parts[0][0]
            if g == 1:
                out = _aria._run_dyn(stat, parts[0][1])
                out = jax.tree.map(lambda x: x[None], out)
                g_run = 1
            else:
                dps = _stack([dp for _, dp in parts])
                g_run = g
                if shard:
                    dps, g_run = _shard_lanes(dps, g)
                out = _aria._run_batch(stat, dps)
            jax.block_until_ready(out.now)
        # only the metrics leaves leave the device (the thread/row
        # state is G x (T,L)/(R,) arrays extract never reads)
        host = jax.device_get(out.g if family == "engine"
                              else _aria.metrics_view(out))
        per_pt = (time.perf_counter() - t0) * 1e6 / n_real
        if family == "engine":
            lane_iters += g_run * int(np.asarray(host.iters).max())
        else:
            lane_iters += g_run * max(
                int(np.asarray(host.now)[j])
                // max(_aria.batch_ticks(p.workload, p.costs), 1)
                for j, p in enumerate(chunk[:n_real]))
        for j, p in enumerate(chunk[:n_real]):
            sliced = _take(host, j)
            if family == "engine":
                metrics[p.name] = extract_globals(p.protocol,
                                                  p.n_threads, sliced)
            else:
                metrics[p.name] = extract_aria(p.n_threads, sliced)
            wall_us[p.name] = per_pt
        n_chunks += 1
    return n_chunks, 0, lane_iters, ()


def run_sweep(points: Iterable[SweepPoint], *, chunk_size: int | None = None,
              thread_bucket: str = "pow2", shard: bool = True,
              compact: bool | None = None, slice_iters: int | None = None,
              verbose: bool = False) -> SweepResults:
    """Run every point, batched per shape bucket. Order is preserved.

    ``chunk_size`` bounds the lanes per device program (vmap width); the
    default adapts to the hardware (see :func:`_auto_chunk`).
    ``compact`` picks the execution path: ``None`` (default) enables the
    lockstep compaction scheduler whenever lanes are actually vmapped
    (``chunk_size > 1``); ``False`` forces the sort-then-cut chunking;
    ``True`` forces compaction even at width 1. ``slice_iters`` overrides
    the per-call iteration budget (default: ~1/8 of the densest lane's
    estimate, floor 256). ``thread_bucket`` picks the bucketing strategy
    (see :func:`_bucket_key`). Results are bit-identical on every path.
    """
    points = list(points)
    names = [p.name for p in points]
    if len(set(names)) != len(names):
        dup = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate sweep point names: {dup[:5]}")
    for p in points:            # fail fast, before any bucket burns time
        if p.protocol not in KNOWN_PROTOCOLS:
            raise ValueError(
                f"sweep point {p.name!r}: unknown protocol "
                f"{p.protocol!r} (known: {', '.join(KNOWN_PROTOCOLS)})")
        if p.protocol == "aria":
            _check_aria_point(p)
    if slice_iters is not None and slice_iters <= 0:
        raise ValueError(f"slice_iters={slice_iters}: must be a positive "
                         "iteration budget (or None for the adaptive "
                         "default)")
    chunk_size = chunk_size or _auto_chunk()
    if compact is None:
        compact = chunk_size > 1

    buckets: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        buckets.setdefault(_bucket_key(p, thread_bucket), []).append(i)

    metrics: dict[str, SimResult] = {}
    wall_us: dict[str, float] = {}
    infos: list[BucketInfo] = []
    compiles0 = _cache_sizes()
    t_start = time.perf_counter()

    for key, idxs in buckets.items():
        family, kind, n_rows = key[:3]
        bpts = [points[i] for i in idxs]
        if len(key) > 3:        # pow2 buckets pad to the (stable) cap
            pad_t, pad_l = key[3], key[4]
        else:                   # "max": pad to the grid max
            pad_t = max(p.n_threads for p in bpts)
            pad_l = max(p.workload.txn_len for p in bpts)
        t_bucket = time.perf_counter()

        if compact:
            stat = _engine.StaticShape(kind=kind, n_threads=pad_t,
                                       txn_len=pad_l, n_rows=n_rows)
            n_chunks, n_rep, lit, rlog = _run_bucket_compact(
                family, stat, bpts, pad_t, pad_l, chunk_size, shard,
                slice_iters, metrics, wall_us)
        else:
            n_chunks, n_rep, lit, rlog = _run_bucket_chunks(
                family, bpts, pad_t, pad_l, chunk_size, shard,
                metrics, wall_us)

        infos.append(BucketInfo(
            family=family, kind=kind, n_rows=n_rows, pad_threads=pad_t,
            pad_len=pad_l, n_points=len(bpts), n_chunks=n_chunks,
            wall_s=time.perf_counter() - t_bucket, compacted=compact,
            n_repacks=n_rep, lane_iters=lit, repack_log=rlog))
        if verbose:
            b = infos[-1]
            print(f"# sweep bucket {family}/{kind}/R{n_rows}: "
                  f"{b.n_points} pts, T<={pad_t}, L<={pad_l}, "
                  f"{b.n_chunks} call(s), {b.n_repacks} repack(s), "
                  f"{b.lane_iters} lane-iters, {b.wall_s:.1f}s")

    return SweepResults(
        points=points, metrics=metrics, wall_us=wall_us, buckets=infos,
        n_compiles=_cache_sizes() - compiles0,
        wall_s=time.perf_counter() - t_start)


def summarize(res: SweepResults, names: Sequence[str] | None = None
              ) -> list[str]:
    """CSV rows (``name,us_per_call,derived``) in benchmark format."""
    return [bench_row(name, res.wall_us[name], res.metrics[name])
            for name in (names if names is not None else res.names())]
