"""Sweep-grid builders: declarative (protocol × workload × threads × ...)
point sets for the batched simulation runner.

A :class:`SweepPoint` is exactly the argument set of
``repro.core.lock.simulate`` (or ``simulate_aria``), plus a name. The
builders here only produce lists of points; ``repro.sweep.runner`` turns
them into vmapped, device-sharded executions.

``grid`` takes each axis as a scalar *or* a sequence and forms the
cartesian product over the sequence-valued ones; ``zip_grid`` zips
equal-length sequences instead (paired axes, e.g. one costs model per
protocol). ``expand`` fans one WorkloadSpec into tagged variants over its
fields (e.g. a Zipf-skew axis).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterable, Mapping, Sequence

from repro.core.lock import CostModel, WorkloadSpec

PROTOCOLS_ALL = ("mysql", "o1", "o2", "group", "bamboo", "brook2pl", "aria")


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One (protocol, workload, threads, ...) measurement request."""
    protocol: str
    workload: WorkloadSpec
    n_threads: int
    horizon: int
    p_abort: float = 0.0
    costs: CostModel = CostModel()
    drain: bool = False
    proto_over: tuple = ()      # sorted (key, value) protocol overrides
    name: str = ""
    tag: str = ""               # workload tag (used by name formatting)

    def over(self) -> dict:
        return dict(self.proto_over)


def _as_axis(v) -> list:
    """Normalize scalar-or-sequence axis values to a list."""
    if isinstance(v, (list, tuple)):
        return list(v)
    return [v]


def _workload_axis(workloads) -> list[tuple[str, WorkloadSpec]]:
    """Normalize workloads to [(tag, spec), ...]."""
    if isinstance(workloads, WorkloadSpec):
        return [(workloads.kind, workloads)]
    if isinstance(workloads, Mapping):
        return [(str(k), v) for k, v in workloads.items()]
    out = []
    for w in workloads:
        if isinstance(w, WorkloadSpec):
            out.append((w.kind, w))
        else:
            tag, spec = w
            out.append((str(tag), spec))
    return out


def _fmt_name(name_fmt: str, protocol: str, tag: str, spec: WorkloadSpec,
              n_threads: int, horizon: int, p_abort: float,
              costs: CostModel) -> str:
    return name_fmt.format(
        protocol=protocol, workload=tag, n_threads=n_threads,
        horizon=horizon, p_abort=p_abort, sync_lat=costs.sync_lat,
        zipf_s=spec.zipf_s, txn_len=spec.txn_len, kind=spec.kind)


def point(protocol: str, workload: WorkloadSpec, n_threads: int, *,
          horizon: int, p_abort: float = 0.0, costs: CostModel | None = None,
          drain: bool = False, name: str = "", tag: str = "",
          **proto_over) -> SweepPoint:
    """Build one fully-explicit sweep point (benchmarks with bespoke names)."""
    return SweepPoint(
        protocol=protocol, workload=workload, n_threads=int(n_threads),
        horizon=int(horizon), p_abort=float(p_abort),
        costs=costs or CostModel(), drain=drain,
        proto_over=tuple(sorted(proto_over.items())),
        name=name or f"{protocol}_{tag or workload.kind}_T{n_threads}",
        tag=tag or workload.kind)


def grid(protocols, workloads, n_threads, *, horizon, p_abort=0.0,
         costs=None, drain: bool = False,
         name_fmt: str = "{protocol}_{workload}_T{n_threads}",
         **proto_over) -> list[SweepPoint]:
    """Cartesian grid over every sequence-valued axis.

    ``protocols``, ``n_threads``, ``horizon``, ``p_abort``, ``costs`` each
    accept a scalar or a sequence; ``workloads`` accepts a WorkloadSpec, a
    {tag: spec} mapping, or a sequence of specs / (tag, spec) pairs.
    ``name_fmt`` may reference {protocol} {workload} {n_threads} {horizon}
    {p_abort} {sync_lat} {zipf_s} {txn_len} {kind}.
    """
    pts = []
    for (tag, spec), t, proto, pab, cm, hz in itertools.product(
            _workload_axis(workloads), _as_axis(n_threads),
            _as_axis(protocols), _as_axis(p_abort),
            _as_axis(costs if costs is not None else CostModel()),
            _as_axis(horizon)):
        cm = cm or CostModel()
        pts.append(point(
            proto, spec, t, horizon=hz, p_abort=pab, costs=cm, drain=drain,
            name=_fmt_name(name_fmt, proto, tag, spec, t, hz, pab, cm),
            tag=tag, **proto_over))
    return pts


def zip_grid(protocols, workloads, n_threads, *, horizon, p_abort=0.0,
             costs=None, drain: bool = False,
             name_fmt: str = "{protocol}_{workload}_T{n_threads}",
             **proto_over) -> list[SweepPoint]:
    """Zip equal-length axes into paired points (scalars broadcast)."""
    axes = {
        "workload": _workload_axis(workloads),
        "n_threads": _as_axis(n_threads),
        "protocol": _as_axis(protocols),
        "p_abort": _as_axis(p_abort),
        "costs": _as_axis(costs if costs is not None else CostModel()),
        "horizon": _as_axis(horizon),
    }
    n = max(len(v) for v in axes.values())
    for k, v in axes.items():
        if len(v) == 1:
            axes[k] = v * n
        elif len(v) != n:
            raise ValueError(f"zip_grid axis {k!r}: length {len(v)} != {n}")
    pts = []
    for (tag, spec), t, proto, pab, cm, hz in zip(
            axes["workload"], axes["n_threads"], axes["protocol"],
            axes["p_abort"], axes["costs"], axes["horizon"]):
        cm = cm or CostModel()
        pts.append(point(
            proto, spec, t, horizon=hz, p_abort=pab, costs=cm, drain=drain,
            name=_fmt_name(name_fmt, proto, tag, spec, t, hz, pab, cm),
            tag=tag, **proto_over))
    return pts


def expand(spec: WorkloadSpec, tag_fmt: str | None = None,
           **field_axes) -> list[tuple[str, WorkloadSpec]]:
    """Fan one WorkloadSpec into tagged variants over its fields.

    >>> expand(WorkloadSpec(kind="zipf"), zipf_s=[0.7, 0.99])
    [("zipf_s0.7", ...), ("zipf_s0.99", ...)]
    """
    keys = list(field_axes)
    out = []
    for combo in itertools.product(*(_as_axis(field_axes[k]) for k in keys)):
        repl = dict(zip(keys, combo))
        variant = dataclasses.replace(spec, **repl)
        if tag_fmt:
            tag = tag_fmt.format(kind=spec.kind, **repl)
        else:
            tag = spec.kind + "_" + "_".join(
                f"{k[0] if len(keys) > 1 else k}{v}" for k, v in repl.items())
        out.append((tag, variant))
    return out
