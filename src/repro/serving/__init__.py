"""Open-system serving layer: arrivals -> bounded engine pool -> tails.

See :mod:`repro.serving.runner` for the serving loop,
:mod:`repro.serving.arrivals` for the schedule generators, and
:mod:`repro.serving.analytic` for the M/M/c validation oracle
(Thomasian, arXiv:2404.02276). DESIGN.md §10 documents the layer.
"""
from .arrivals import (ArrivalSchedule, bursty, flash_crowd, poisson,
                       saturating, uniform)
from .runner import (ServeCell, ServeResults, ServingRecord, ServingResult,
                     serve)
from .analytic import (erlang_c, mmc_wait_ticks, pool_capacity_tps,
                       predicted_response_ticks, predicted_util,
                       service_ticks, write_fraction)
from .metrics import MetricFamily, ServingMetrics, render_families

__all__ = [
    "ArrivalSchedule", "poisson", "bursty", "flash_crowd", "uniform",
    "saturating",
    "ServeCell", "ServeResults", "ServingRecord", "ServingResult", "serve",
    "erlang_c", "mmc_wait_ticks", "pool_capacity_tps",
    "predicted_response_ticks", "predicted_util", "service_ticks",
    "write_fraction",
    "MetricFamily", "ServingMetrics", "render_families",
]
