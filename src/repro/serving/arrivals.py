"""Arrival processes for the open-system serving layer.

An :class:`ArrivalSchedule` is a named, seeded request-arrival stream: a
sorted array of integer arrival ticks over ``[0, horizon)`` plus the
metadata the analytic layer (``serving.analytic``) and the results store
need to reason about it. Like the drift schedules in ``workload.py``,
schedules are generated eagerly on the host (numpy, seeded) so every
consumer — a serving lane, a repeated run, a test re-deriving the same
stream — sees bit-identical arrival times; nothing here is traced, because
arrivals are *host* events: the serving runner admits them at segment
boundaries and meters the device-side pool through traced credits
(``DynParams.txn_cap``), see DESIGN.md §10.

Kinds:

* :func:`poisson` — homogeneous Poisson(rate); the M/M/c validation
  regime (Thomasian, arXiv:2404.02276).
* :func:`bursty` — on/off modulated Poisson: ``burst_rate`` for a
  ``duty`` fraction of every ``period``, ``base_rate`` otherwise.
* :func:`flash_crowd` — rate step at a fraction of the horizon (the
  serving analogue of the drift schedule of the same name).
* :func:`uniform` — deterministic evenly-spaced arrivals (analysis and
  differential tests).
* :func:`saturating` — every request present at tick 0: the queue never
  empties, the pool never idles, and the open-system path must reproduce
  the closed-loop engine bit-exactly (tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

TICKS_PER_SEC = 10_000_000  # 1 tick = 0.1us (metrics.TICKS_PER_SEC)


@dataclasses.dataclass(frozen=True)
class ArrivalSchedule:
    """A named request-arrival stream over ``[0, horizon)`` ticks."""
    name: str
    times: np.ndarray           # (N,) sorted int64 arrival ticks
    horizon: int
    seed: int = 0

    def __post_init__(self):
        t = np.asarray(self.times, dtype=np.int64)
        assert (np.diff(t) >= 0).all(), "arrival times must be sorted"
        assert t.size == 0 or (t[0] >= 0 and t[-1] < self.horizon), (
            "arrivals must lie in [0, horizon)")
        object.__setattr__(self, "times", t)

    @property
    def n(self) -> int:
        return int(self.times.size)

    @property
    def offered_tps(self) -> float:
        """Offered load in transactions/second of simulated time."""
        return self.n * TICKS_PER_SEC / max(self.horizon, 1)

    def meta(self) -> dict:
        return {"name": self.name, "n": self.n, "horizon": self.horizon,
                "seed": self.seed, "offered_tps": self.offered_tps}


def _finish(kind: str, times: np.ndarray, horizon: int,
            seed: int) -> ArrivalSchedule:
    times = np.sort(times.astype(np.int64))
    times = times[(times >= 0) & (times < horizon)]
    return ArrivalSchedule(kind, times, int(horizon), int(seed))


def poisson(rate: float, horizon: int, *, seed: int = 0) -> ArrivalSchedule:
    """Homogeneous Poisson arrivals: ``rate`` requests per tick.

    Generated as cumulative exponential gaps (inverse-CDF, float64) and
    floored to integer ticks; same-tick arrivals are legal (the queue
    absorbs them).
    """
    assert rate > 0
    rng = np.random.default_rng(seed)
    # enough gaps to overshoot the horizon w.h.p., then trim
    n_draw = int(rate * horizon * 1.25) + 64
    gaps = rng.exponential(1.0 / rate, size=n_draw)
    t = np.cumsum(gaps)
    while t.size and t[-1] < horizon:    # rare undershoot: extend
        extra = rng.exponential(1.0 / rate, size=n_draw)
        t = np.concatenate([t, t[-1] + np.cumsum(extra)])
    return _finish("poisson", np.floor(t), horizon, seed)


def bursty(base_rate: float, burst_rate: float, horizon: int, *,
           period: int, duty: float = 0.25,
           seed: int = 0) -> ArrivalSchedule:
    """On/off modulated Poisson: ``burst_rate`` during the first ``duty``
    fraction of every ``period`` ticks, ``base_rate`` otherwise."""
    assert 0.0 < duty < 1.0 and period > 0
    rng = np.random.default_rng(seed)
    peak = max(base_rate, burst_rate)
    # thinning: draw at the peak rate, keep per-phase
    n_draw = int(peak * horizon * 1.25) + 64
    t = np.cumsum(rng.exponential(1.0 / peak, size=n_draw))
    t = t[t < horizon]
    in_burst = (t % period) < duty * period
    p_keep = np.where(in_burst, burst_rate / peak, base_rate / peak)
    keep = rng.random(t.size) < p_keep
    return _finish("bursty", np.floor(t[keep]), horizon, seed)


def flash_crowd(base_rate: float, spike_rate: float, horizon: int, *,
                at: float = 0.5, spike_frac: float = 0.25,
                seed: int = 0) -> ArrivalSchedule:
    """Rate step: ``base_rate`` until ``at * horizon``, then
    ``spike_rate`` for ``spike_frac * horizon`` ticks, then base again."""
    rng = np.random.default_rng(seed)
    t0, t1 = int(at * horizon), int((at + spike_frac) * horizon)
    peak = max(base_rate, spike_rate)
    n_draw = int(peak * horizon * 1.25) + 64
    t = np.cumsum(rng.exponential(1.0 / peak, size=n_draw))
    t = t[t < horizon]
    in_spike = (t >= t0) & (t < min(t1, horizon))
    p_keep = np.where(in_spike, spike_rate / peak, base_rate / peak)
    keep = rng.random(t.size) < p_keep
    return _finish("flash_crowd", np.floor(t[keep]), horizon, seed)


def uniform(rate: float, horizon: int, *, seed: int = 0) -> ArrivalSchedule:
    """Deterministic evenly-spaced arrivals at ``rate`` per tick."""
    assert rate > 0
    n = int(rate * horizon)
    t = np.floor(np.arange(n, dtype=np.float64) / rate)
    return _finish("uniform", t, horizon, seed)


def saturating(n: int, horizon: int) -> ArrivalSchedule:
    """All ``n`` requests arrive at tick 0 (the closed-loop limit).

    With ``n`` large enough that the queue outlives the horizon, every
    pool slot always has a next request — the regime where the serving
    path must be bit-identical to closed-loop ``simulate()``.
    """
    return _finish("saturating", np.zeros(n), horizon, 0)
