"""Analytic open-system predictions (Thomasian, arXiv:2404.02276).

Thomasian's heterogeneous-data-access model treats an OLTP system as a
multi-server queue whose response time is service time plus queueing
delay, with lock contention entering as a service-time inflation. In the
low-contention regime (large key space, short transactions) the inflation
vanishes and the serving layer must match the plain M/M/c prediction —
that is the closed-form oracle tests/test_serving.py validates against,
the same differential-validation pattern ``ref_engine`` applies to the
closed-loop engine.

Pieces:

* :func:`service_ticks` — the uncontended per-transaction service time
  implied by the cost model (the chain ``ref_engine`` uses, generalized
  to read/write mixes).
* :func:`erlang_c` / :func:`mmc_wait_ticks` — the M/M/c queueing delay
  for ``c`` pool slots at arrival rate ``lam``.
* :func:`predicted_response_ticks` / :func:`predicted_util` — what the
  serving layer should measure below the knee, before boundary
  quantization (the runner observes completions only at segment
  boundaries; see DESIGN.md §10 for the ``+seg_ticks`` correction).

Service in the engine is near-deterministic, so the true queue is M/D/c
whose delay is about half of M/M/c's — both are well inside the test
tolerance below the knee, where delay is a small fraction of service
time. Above the knee (``rho >= 1``) the open system has no steady state:
the queue grows linearly and percentiles are horizon-bound, which is the
regime the fig17 knee curves exhibit rather than predict.
"""
from __future__ import annotations

import math

from repro.core.lock.costs import CostModel, ProtocolParams, protocol_params
from repro.core.lock.metrics import TICKS_PER_SEC
from repro.core.lock.workload import WorkloadSpec

# workload kinds whose non-structural ops write with prob. write_ratio;
# structural slots (hotspot/fit/tpcc op 0..) are handled per kind below.
_ALL_WRITE_KINDS = ("zipf", "hotspot_scan")


def write_fraction(w: WorkloadSpec) -> float:
    """Expected fraction of a transaction's ops that are (locking) writes."""
    if w.reads_lock:
        return 1.0
    if w.kind in _ALL_WRITE_KINDS:
        return 1.0
    L = w.txn_len
    if w.kind == "hotspot_update":
        return (1.0 + (L - 1) * w.write_ratio) / L
    if w.kind in ("fit", "tpcc"):
        forced = min(2, L)
        return (forced + (L - forced) * w.write_ratio) / L
    return w.write_ratio        # uniform, hotspot_mix


def service_ticks(w: WorkloadSpec, costs: CostModel,
                  protocol: str | ProtocolParams = "mysql") -> float:
    """Uncontended mean service time of one transaction, in ticks.

    Every write op pays ``lock_base`` (instant uncontended grant; the
    deadlock-detection term is 0 at queue length 0) plus ``op_exec``;
    every read pays ``read_exec``; commit pays ``commit_base +
    sync_lat``. Duplicate-key writes (no fresh ticket) are ignored — they
    are vanishingly rare in the large-R regime this oracle serves.
    """
    p = (protocol_params(protocol) if isinstance(protocol, str)
         else protocol)
    fw = write_fraction(w)
    per_op = fw * (p.lock_base + costs.op_exec) + (1 - fw) * costs.read_exec
    return w.txn_len * per_op + costs.commit_base + costs.sync_lat


def erlang_c(c: int, a: float) -> float:
    """P(wait) in an M/M/c queue at offered load ``a = lam/mu`` erlangs.

    Computed via the numerically stable Erlang-B recurrence; requires
    ``a < c`` (below saturation).
    """
    assert 0 <= a < c
    b = 1.0
    for k in range(1, c + 1):
        b = a * b / (k + a * b)
    rho = a / c
    return b / (1.0 - rho + rho * b)


def mmc_wait_ticks(lam: float, s: float, c: int) -> float:
    """Mean M/M/c queueing delay (ticks) at ``lam`` arrivals/tick,
    service time ``s`` ticks, ``c`` servers. inf at/above saturation."""
    a = lam * s
    if a >= c:
        return math.inf
    return erlang_c(c, a) * s / (c - a)


def predicted_response_ticks(lam: float, w: WorkloadSpec, costs: CostModel,
                             c: int,
                             protocol: str | ProtocolParams = "mysql"
                             ) -> float:
    """Low-contention mean response time (ticks): service + M/M/c delay."""
    s = service_ticks(w, costs, protocol)
    return s + mmc_wait_ticks(lam, s, c)


def predicted_util(lam: float, w: WorkloadSpec, costs: CostModel, c: int,
                   protocol: str | ProtocolParams = "mysql") -> float:
    """Pool utilization ``lam * s / c`` (== engine ``cpu_util`` in the
    uncontended regime, where busy ticks are exactly service ticks)."""
    return min(lam * service_ticks(w, costs, protocol) / c, 1.0)


def pool_capacity_tps(w: WorkloadSpec, costs: CostModel, c: int,
                      protocol: str | ProtocolParams = "mysql") -> float:
    """Contention-free pool capacity (the knee's upper bound), in TPS."""
    return c * TICKS_PER_SEC / service_ticks(w, costs, protocol)
