"""Open-system serving loop over the segmented lock engine.

A :class:`ServeCell` is one served pool: an arrival schedule
(``serving.arrivals``), a workload, ``n_threads`` device-resident engine
slots, and an admission policy. :func:`serve` runs every cell as a
sequence of resumable engine segments (``run_packed_segment``, the same
substrate the governed runner rides) and layers the open-system mechanics
on the host, at segment boundaries only:

* **admission** — arrivals with time <= the boundary enter a host FIFO
  queue, bounded by ``queue_cap`` (``admission`` picks what happens at
  the bound: reject the newcomer, shed the oldest, or wait = unbounded).
* **dispatch** — queued requests become per-thread *credits*: thread
  ``t``'s traced transaction quota (``DynParams.txn_cap[t]``) is raised
  by one per assigned request (round-robin, least-outstanding first,
  bounded by ``max_outstanding`` per slot). The engine halts a slot the
  instant its quota is exhausted, so between boundaries the device runs
  exactly the dispatched work — the pool is closed-loop *within* a
  segment, open *across* them.
* **retire** — completions are read off the device as per-thread ``txn``
  counter deltas (a committed or user-aborted transaction is a completed
  request; forced aborts retry and complete later) and matched FIFO
  against the thread's assigned arrival ticks: response time = boundary
  observation time − arrival tick. Freed slots (quota exhausted → phase
  HALT) are revived by flipping HALT→START for any slot holding fresh
  credits — outstanding == 0 at a boundary *implies* HALT (the quota
  check sits on the same iteration that completes the final credited
  txn), so revival needs no phase readback.

Because ``txn_cap`` is traced like every other engine parameter, the
serving path adds nothing to the compile key: a serving run reuses the
closed-loop segment executables, and a repeated run compiles nothing
(asserted in tests/test_serving.py). With a saturating schedule and
unbounded per-slot credit the quota never binds and the device-side state
evolution is bit-identical to closed-loop ``simulate()`` — the parity
anchor for everything else this layer reports. See DESIGN.md §10.

Governed serving: give a cell a ``policy`` (``repro.adaptive.governor``)
and it re-decides the preset each boundary from the same
:class:`SegmentRecord` history the governed runner feeds it; the
resolver-free-preset switch rules (brook, DESIGN.md §9.2) are enforced
here identically. Workloads don't drift under serving, so only the
ordered-prefix rule can trip (the chop rank table is static).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.lock import engine as _engine
from repro.core.lock.costs import CostModel
from repro.core.lock.engine import EngineConfig, I32, N_HIST
from repro.core.lock.metrics import (SimResult, TICKS_PER_SEC,
                                     _pct_from_hist, extract_globals,
                                     extract_segment)
from repro.core.lock.workload import WorkloadSpec
from repro.sweep.grid import SweepPoint
from repro.sweep.runner import (BucketInfo, SweepResults, MIN_T_BUCKET,
                                _auto_chunk, _pow2ceil, _take,
                                run_packed_segment)
from repro.adaptive.governor import (PRESETS, Policy, SegmentRecord,
                                     preset_params, switch_safe)
from repro.obs import compile_log as _compile_log

from .arrivals import ArrivalSchedule

ADMISSIONS = ("reject", "shed", "wait")


@dataclasses.dataclass(frozen=True)
class ServeCell:
    """One served engine pool: arrivals in, responses + telemetry out."""
    name: str
    schedule: ArrivalSchedule
    workload: WorkloadSpec
    n_threads: int                  # pool slots (device threads)
    preset: str = "mysql"           # governor preset (PRESETS name)
    policy: Policy | None = None    # optional: re-decide preset per segment
    costs: CostModel = CostModel()
    p_abort: float = 0.0
    queue_cap: int = 256            # backpressure bound (ignored by "wait")
    admission: str = "reject"       # reject newcomer | shed oldest | wait
    max_outstanding: int = 2        # dispatched-but-unfinished cap per slot
    sla_us: float = 0.0             # response-time SLA (0: no SLA account)
    attrib: bool = False            # per-record contention accumulator

    def __post_init__(self):
        assert self.preset in PRESETS, self.preset
        assert self.admission in ADMISSIONS, self.admission
        assert self.max_outstanding >= 1
        assert self.n_threads >= 1

    def label(self) -> str:
        return self.policy.name if self.policy else self.preset


@dataclasses.dataclass(frozen=True)
class ServingRecord:
    """One serving boundary: engine window metrics + queue accounting."""
    index: int
    t0: int                 # window entry sim-time (ticks)
    t1: int                 # window exit sim-time (observation point)
    preset: str
    metrics: SimResult      # engine counter deltas over [t0, t1]
    arrived: int            # arrivals admitted-or-refused this window
    rejected: int
    shed: int
    completed: int          # responses observed at t1
    qlen: int               # queue length after dispatch at t1
    in_flight: int          # dispatched, not yet completed, at t1
    p50_us: float           # response-time percentiles of this window's
    p99_us: float           # completions (0 when none completed)
    p999_us: float
    sla_miss: int           # window completions past the SLA
    max_qlen: int           # engine snapshot telemetry at t1 (row queue —
    n_waiting: int          # not the arrival queue) for governor parity

    def as_json(self) -> dict:
        m = self.metrics
        return {
            "index": self.index, "t0": self.t0, "t1": self.t1,
            "preset": self.preset, "tps": m.tps, "commits": m.commits,
            "abort_rate": m.abort_rate, "lock_wait_frac": m.lock_wait_frac,
            "cpu_util": m.cpu_util, "arrived": self.arrived,
            "rejected": self.rejected, "shed": self.shed,
            "completed": self.completed, "qlen": self.qlen,
            "in_flight": self.in_flight, "p50_us": self.p50_us,
            "p99_us": self.p99_us, "p999_us": self.p999_us,
            "sla_miss": self.sla_miss, "max_qlen": self.max_qlen,
            "n_waiting": self.n_waiting,
            # v3 addition: per-window TickBreakdown (ticks per bin,
            # branches summed; conserves to pad_T * (t1 - t0))
            "breakdown": dict(m.breakdown),
            # v4 addition: per-window top-K contended records (empty when
            # ServeCell.attrib is off) — see adaptive.SegmentRecord
            "hotspots": [dict(h) for h in getattr(m, "hotspots", [])],
        }


@dataclasses.dataclass(frozen=True)
class ServingResult:
    """Whole-run open-system summary for one cell."""
    name: str
    label: str
    schedule: dict              # ArrivalSchedule.meta()
    offered_tps: float
    completed_tps: float        # responses (commits + user aborts) per sec
    goodput_tps: float          # engine commits per sec
    arrived: int
    rejected: int
    shed: int
    dispatched: int
    completed: int
    qlen_end: int
    in_flight_end: int
    mean_resp_us: float
    p50_us: float
    p99_us: float
    p999_us: float
    max_us: float
    sla_us: float
    sla_miss: int
    sla_miss_frac: float        # misses / completions (0 when no SLA)
    utilization: float          # engine cpu_util over the whole run
    engine: SimResult           # closed-loop-style engine metrics

    def as_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["engine"] = dataclasses.asdict(self.engine)
        return d


@dataclasses.dataclass
class ServeResults(SweepResults):
    """SweepResults (store/bench compatible) + per-cell serving summaries.

    ``states`` (``serve(..., return_states=True)``) maps cell name to the
    final device ``SimState`` — the differential tests compare its leaves
    bit-for-bit against closed-loop ``simulate()``.
    """
    serving: dict[str, ServingResult] = dataclasses.field(
        default_factory=dict)
    states: dict = dataclasses.field(default_factory=dict)
    # raw response times in us per cell, only when serve(...,
    # keep_responses=True) — the parity check for the histogram
    # percentiles; empty by default (horizon-scale runs must not haul
    # O(completions) floats to host)
    responses: dict = dataclasses.field(default_factory=dict)


def _seg_compiles() -> int:
    return (_engine._run_seg_dyn._cache_size()
            + _engine._run_seg_batch._cache_size())


def _cell_config(cell: ServeCell, preset: str,
                 seg_ticks: int | None = None) -> EngineConfig:
    horizon = cell.schedule.horizon
    n_segments = max(1, horizon // seg_ticks) if seg_ticks else None
    return EngineConfig(
        protocol=preset_params(preset, horizon=horizon,
                               n_segments=n_segments),
        costs=cell.costs,
        workload=cell.workload, n_threads=cell.n_threads,
        horizon=horizon, p_abort=cell.p_abort, attrib=cell.attrib)


def _pctl(resp_us: list, q: float) -> float:
    return float(np.percentile(np.asarray(resp_us), q)) if resp_us else 0.0


@jax.jit
def _hist_add(hist, ticks, valid):
    """Fold a padded batch of response ticks into the engine's log-bucket
    histogram (same buckets as the commit-latency histogram, so both
    percentile paths share ``_pct_from_hist``)."""
    return hist.at[_engine._hist_bucket(ticks)].add(
        jnp.where(valid, 1, 0), mode="drop")


_compile_log.register(_hist_add)


def _resp_hist_update(hist, resp_ticks: list):
    """Host shim: pad the boundary's completions to a pow2 width (bounded
    executable ladder — boundary sizes vary freely, compiles don't)."""
    n = len(resp_ticks)
    if n == 0:
        return hist
    W = max(64, 1 << (n - 1).bit_length())
    t = np.zeros(W, dtype=np.int32)
    t[:n] = resp_ticks
    v = np.zeros(W, dtype=bool)
    v[:n] = True
    return _hist_add(hist, jnp.asarray(t), jnp.asarray(v))


class _Lane:
    """Host-side open-system bookkeeping for one cell (device holds the
    pool state; this mirror holds the queue, credits, and arrival times)."""

    def __init__(self, cell: ServeCell, keep_responses: bool = False):
        self.cell = cell
        self.arr = cell.schedule.times
        self.ptr = 0                            # next unadmitted arrival
        self.queue: deque[int] = deque()        # admitted, undispatched
        self.assigned = [deque() for _ in range(cell.n_threads)]
        self.caps = np.zeros(cell.n_threads, dtype=np.int64)
        self.txn = np.zeros(cell.n_threads, dtype=np.int64)
        self.arrived = self.rejected = self.shed = 0
        self.dispatched = self.completed = self.sla_miss = 0
        # whole-run response accounting is histogram-based (device log
        # buckets + exact sum/max) so memory is O(N_HIST), not
        # O(completions); the raw list is opt-in for parity tests
        self.resp_hist = jnp.zeros((N_HIST,), I32)
        self.resp_sum_ticks = 0
        self.resp_max_ticks = 0
        self.resp_us: list[float] | None = [] if keep_responses else None
        self.history: list[SegmentRecord] = []
        self.records: list[ServingRecord] = []
        self.g_prev = None                      # host Globals snapshot
        self.all_ordered = True                 # switch-safety mirror

    def admit(self, boundary: int) -> tuple[int, int, int]:
        """Admit every not-yet-seen arrival with time <= boundary."""
        c = self.cell
        n_arr = n_rej = n_shed = 0
        while self.ptr < self.arr.size and self.arr[self.ptr] <= boundary:
            t = int(self.arr[self.ptr])
            self.ptr += 1
            n_arr += 1
            if c.admission == "wait" or len(self.queue) < c.queue_cap:
                self.queue.append(t)
            elif c.admission == "reject":
                n_rej += 1
            else:                               # shed: drop the oldest
                self.queue.popleft()
                self.queue.append(t)
                n_shed += 1
        self.arrived += n_arr
        self.rejected += n_rej
        self.shed += n_shed
        return n_arr, n_rej, n_shed

    def dispatch(self) -> None:
        """Queue -> per-slot credits, round-robin least-outstanding first.

        Each round tops up every slot below ``max_outstanding`` by one
        credit in (outstanding, tid) order, so the load spreads evenly
        and deterministically; stops when the queue drains or every slot
        is at its cap.
        """
        c = self.cell
        out = self.caps - self.txn
        while self.queue:
            order = sorted(range(c.n_threads), key=lambda t: (out[t], t))
            moved = False
            for t in order:
                if not self.queue:
                    break
                if out[t] >= c.max_outstanding:
                    continue
                self.assigned[t].append(self.queue.popleft())
                self.caps[t] += 1
                out[t] += 1
                self.dispatched += 1
                moved = True
            if not moved:
                break

    def retire(self, txn_now: np.ndarray, t1: int) -> tuple[int, list]:
        """Match per-thread txn deltas to assigned arrivals, FIFO."""
        c = self.cell
        window: list[float] = []
        rts: list[int] = []
        for t in range(c.n_threads):
            d = int(txn_now[t]) - int(self.txn[t])
            assert 0 <= d <= len(self.assigned[t]), (
                f"cell {c.name!r} slot {t}: {d} completions vs "
                f"{len(self.assigned[t])} assigned — credit ledger broken")
            for _ in range(d):
                rt = t1 - self.assigned[t].popleft()       # ticks, exact
                rts.append(rt)
                resp = rt / 10.0                           # -> us
                window.append(resp)
                if self.resp_us is not None:
                    self.resp_us.append(resp)
                if c.sla_us > 0 and resp > c.sla_us:
                    self.sla_miss += 1
        if rts:
            self.resp_hist = _resp_hist_update(self.resp_hist, rts)
            self.resp_sum_ticks += sum(rts)
            self.resp_max_ticks = max(self.resp_max_ticks, max(rts))
        self.txn = txn_now.astype(np.int64)
        self.completed += len(window)
        return len(window), window

    @property
    def in_flight(self) -> int:
        return int((self.caps - self.txn).sum())

    def check_conservation(self, where: str) -> None:
        """Every request is exactly one of: rejected, shed, queued,
        in flight, completed — asserted at every boundary, not just at
        the end (the property tests re-check this from the records)."""
        lhs = self.arrived
        rhs = (self.rejected + self.shed + len(self.queue)
               + self.dispatched)
        assert lhs == rhs, (
            f"cell {self.cell.name!r} @ {where}: arrived {lhs} != "
            f"rejected {self.rejected} + shed {self.shed} + queued "
            f"{len(self.queue)} + dispatched {self.dispatched}")
        assert self.dispatched == self.completed + self.in_flight, (
            f"cell {self.cell.name!r} @ {where}: dispatched "
            f"{self.dispatched} != completed {self.completed} + in-flight "
            f"{self.in_flight}")

    def cap_vector(self, pad_t: int) -> jnp.ndarray:
        """The segment's traced per-thread quota (padded slots get 0 —
        they are masked HALT by ``n_active`` anyway)."""
        v = np.zeros(pad_t, dtype=np.int64)
        v[:self.cell.n_threads] = self.caps
        assert v.max() < 2**30, "credit counter would overflow the i32 INF"
        return jnp.asarray(v, I32)

    def revive_row(self, pad_t: int) -> np.ndarray:
        """Slots holding unserved credits must be running. Outstanding
        == 0 implies the engine HALTed the slot (quota check rides the
        commit iteration), so flipping HALT->START exactly on
        ``caps > txn`` wakes every refilled slot and nothing else."""
        row = np.zeros(pad_t, dtype=bool)
        row[:self.cell.n_threads] = self.caps > self.txn
        return row


def _revive(packed, width: int, rows: np.ndarray):
    """Flip HALT->START on the packed pool state (device-side where; no
    phase readback). ``rows`` is (width, T) bool; only genuinely HALTed
    slots change, so a wrong host mirror could never corrupt a live one."""
    ph = packed.th.phase
    m = jnp.asarray(rows[0] if width == 1 else rows)
    new = jnp.where(m & (ph == _engine.HALT), I32(_engine.START), ph)
    return packed._replace(th=packed.th._replace(phase=new))


def serve(cells: Iterable[ServeCell], *, seg_ticks: int,
          chunk_size: int | None = None, return_states: bool = False,
          keep_responses: bool = False, metrics_registry=None,
          verbose: bool = False) -> ServeResults:
    """Serve every cell's arrival schedule over its horizon.

    ``seg_ticks`` sets the boundary grid (admission/dispatch/observation
    points): boundaries at ``seg_ticks, 2*seg_ticks, ..., horizon``. All
    cells must share one horizon — lanes advance through shared
    boundaries so bucket-mates ride one packed program. Smaller segments
    mean finer admission latency and response-time resolution but more
    host round-trips; DESIGN.md §10 discusses the quantization.

    Returns :class:`ServeResults`: SweepResults-compatible (metrics /
    segments / store) plus ``serving[name]`` summaries. Whole-run
    percentiles (p50/p99/p999) come from the device-side log-bucket
    response histogram (memory O(N_HIST) regardless of horizon);
    ``keep_responses=True`` additionally keeps every raw response in
    ``ServeResults.responses[name]`` for parity checks.

    ``metrics_registry`` (a :class:`repro.serving.metrics.ServingMetrics`)
    is fed every boundary record as it is produced — the live-scrape
    path: render/dump/serve_http it concurrently from another thread.
    """
    cells = list(cells)
    assert cells and seg_ticks >= 1
    names = [c.name for c in cells]
    if len(set(names)) != len(names):
        dup = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate serve cell names: {dup[:5]}")
    horizons = {c.schedule.horizon for c in cells}
    if len(horizons) != 1:
        raise ValueError(f"serve cells must share one horizon, got "
                         f"{sorted(horizons)}")
    horizon = horizons.pop()
    chunk_size = chunk_size or _auto_chunk()

    bounds = list(range(seg_ticks, horizon, seg_ticks)) + [horizon]

    buckets: dict[tuple, list[int]] = {}
    for i, c in enumerate(cells):
        w = c.workload
        pad_t = _pow2ceil(c.n_threads, MIN_T_BUCKET)
        buckets.setdefault((w.kind, w.n_rows, pad_t, w.txn_len),
                           []).append(i)

    metrics, wall_us, segments = {}, {}, {}
    serving: dict[str, ServingResult] = {}
    states_out: dict[str, object] = {}
    responses_out: dict[str, list] = {}
    infos: list[BucketInfo] = []
    compiles0 = _seg_compiles()
    t_start = time.perf_counter()

    for key, idxs in buckets.items():
        kind, n_rows, pad_t, pad_l = key
        bcells = [cells[i] for i in idxs]
        G = len(bcells)
        t_bucket = time.perf_counter()

        lanes = [_Lane(c, keep_responses) for c in bcells]
        for c in bcells:
            if c.policy is not None:
                c.policy.reset(c.n_threads)
        presets = [c.policy.decide(0, []) if c.policy else c.preset
                   for c in bcells]

        # boundary 0: admit the opening arrivals, dispatch the first
        # credits, then build the initial device states (phase START is
        # correct everywhere: credit-less slots self-HALT on their first
        # quota check, credited slots run)
        stat = None
        states = []
        prologue = []           # t=0 admissions, folded into record 0
        for ln, c, p in zip(lanes, bcells, presets):
            prologue.append(ln.admit(0))
            ln.dispatch()
            ln.check_conservation("t=0")
            st, dp0 = _engine.split_config(_cell_config(c, p, seg_ticks),
                                           pad_threads=pad_t,
                                           pad_len=pad_l)
            assert stat is None or st == stat
            stat = st
            s0 = _engine.init_state_dyn(st, dp0)
            states.append(s0)
            ln.g_prev = jax.device_get(s0.g)
            ln.all_ordered = bool(preset_params(p).ordered_acquire)

        groups = [list(range(lo, min(lo + chunk_size, G)))
                  for lo in range(0, G, max(chunk_size, 1))]
        gpacked: list = [None] * len(groups)
        gwidth: list = [0] * len(groups)

        for k, until in enumerate(bounds):
            if k:
                presets = [c.policy.decide(k, ln.history)
                           if c.policy else c.preset
                           for c, ln in zip(bcells, lanes)]
            dps = []
            for ln, c, p in zip(lanes, bcells, presets):
                if k and not switch_safe(p) and not ln.all_ordered:
                    # same rule as run_governed; serving workloads are
                    # static so the rank-rotation clause can't trip
                    raise ValueError(
                        f"serve cell {c.name!r}: policy {c.label()!r} "
                        f"runs resolver-free preset {p!r} at boundary "
                        f"{k} after an unordered-preset segment; "
                        "inherited out-of-order locks can cycle "
                        "unresolvably — use 'brook_guard' "
                        "(DESIGN.md §9.2)")
                ln.all_ordered &= bool(preset_params(p).ordered_acquire)
                dp = _engine.split_config(_cell_config(c, p, seg_ticks),
                                          pad_threads=pad_t,
                                          pad_len=pad_l)[1]
                dps.append(dp._replace(txn_cap=ln.cap_vector(pad_t)))

            for gi, grp in enumerate(groups):
                packed = gpacked[gi]
                if packed is not None:
                    rows = np.stack([lanes[j].revive_row(pad_t)
                                     for j in grp]
                                    + [np.zeros(pad_t, dtype=bool)]
                                    * (gwidth[gi] - len(grp)))
                    packed = _revive(packed, gwidth[gi], rows)
                gpacked[gi], snaps, w = run_packed_segment(
                    stat, [dps[j] for j in grp],
                    [states[j] for j in grp], [until] * len(grp),
                    packed=packed)
                gwidth[gi] = w
                g_host, txn_host, snap_host = jax.device_get(
                    (gpacked[gi].g, gpacked[gi].th.txn, snaps))
                for lane_i, j in enumerate(grp):
                    ln, c, p = lanes[j], bcells[j], presets[j]
                    if w == 1:
                        g_now, txn_now, snap = g_host, txn_host, snap_host
                    else:
                        g_now = _take(g_host, lane_i)
                        txn_now = txn_host[lane_i]
                        snap = _take(snap_host, lane_i)
                    t0, t1 = int(ln.g_prev.now), int(g_now.now)
                    n_done, window = ln.retire(
                        txn_now[:c.n_threads], t1)
                    n_arr, n_rej, n_shed = ln.admit(until)
                    if k == 0:      # attribute the t=0 prologue here so
                                    # the records sum to the lane totals
                        p_arr, p_rej, p_shed = prologue[j]
                        n_arr += p_arr
                        n_rej += p_rej
                        n_shed += p_shed
                    ln.dispatch()
                    ln.check_conservation(f"t={until}")
                    r = extract_segment(p, c.n_threads, ln.g_prev, g_now)
                    ln.history.append(SegmentRecord(
                        index=k, t0=t0, t1=t1, preset=p, metrics=r,
                        max_qlen=int(snap.max_qlen),
                        n_hot=int(snap.n_hot),
                        n_live=int(snap.n_live),
                        n_waiting=int(snap.n_waiting),
                        wait_hist=tuple(int(v) for v in snap.wait_hist),
                        occ_hist=tuple(int(v) for v in snap.occ_hist)))
                    ln.records.append(ServingRecord(
                        index=k, t0=t0, t1=t1, preset=p, metrics=r,
                        arrived=n_arr, rejected=n_rej, shed=n_shed,
                        completed=n_done, qlen=len(ln.queue),
                        in_flight=ln.in_flight,
                        p50_us=_pctl(window, 50.0),
                        p99_us=_pctl(window, 99.0),
                        p999_us=_pctl(window, 99.9),
                        sla_miss=sum(1 for u in window
                                     if c.sla_us > 0 and u > c.sla_us),
                        max_qlen=int(snap.max_qlen),
                        n_waiting=int(snap.n_waiting)))
                    if metrics_registry is not None:
                        metrics_registry.observe(c.name, ln.records[-1])
                    ln.g_prev = g_now

        if return_states:
            for gi, grp in enumerate(groups):
                for lane_i, j in enumerate(grp):
                    states_out[bcells[j].name] = (
                        gpacked[gi] if gwidth[gi] == 1
                        else _take(gpacked[gi], lane_i))

        wall_b = time.perf_counter() - t_bucket
        for ln, c in zip(lanes, bcells):
            eng = extract_globals(c.label(), c.n_threads, ln.g_prev)
            metrics[c.name] = eng
            wall_us[c.name] = wall_b * 1e6 / G
            segments[c.name] = [rec.as_json() for rec in ln.records]
            sim_s = horizon / TICKS_PER_SEC
            # whole-run percentiles from the device histogram: bucket
            # midpoints, clamped to the exact observed max so
            # p50 <= p99 <= p999 <= max holds regardless of bucket edges
            hist_np = np.asarray(ln.resp_hist)
            assert int(hist_np.sum()) == ln.completed, (
                f"cell {c.name!r}: response histogram holds "
                f"{int(hist_np.sum())} responses, lane completed "
                f"{ln.completed}")
            max_us = ln.resp_max_ticks / 10.0
            pct = lambda q: min(_pct_from_hist(hist_np, q), max_us)
            if keep_responses:
                responses_out[c.name] = list(ln.resp_us)
            serving[c.name] = ServingResult(
                name=c.name, label=c.label(),
                schedule=c.schedule.meta(),
                offered_tps=c.schedule.offered_tps,
                completed_tps=ln.completed / sim_s,
                goodput_tps=eng.tps,
                arrived=ln.arrived, rejected=ln.rejected, shed=ln.shed,
                dispatched=ln.dispatched, completed=ln.completed,
                qlen_end=len(ln.queue), in_flight_end=ln.in_flight,
                mean_resp_us=(ln.resp_sum_ticks / ln.completed / 10.0
                              if ln.completed else 0.0),
                p50_us=pct(0.50),
                p99_us=pct(0.99),
                p999_us=pct(0.999),
                max_us=max_us,
                sla_us=c.sla_us, sla_miss=ln.sla_miss,
                sla_miss_frac=(ln.sla_miss / ln.completed
                               if c.sla_us > 0 and ln.completed else 0.0),
                utilization=eng.cpu_util, engine=eng)
        infos.append(BucketInfo(
            family="serving", kind=kind, n_rows=n_rows, pad_threads=pad_t,
            pad_len=pad_l, n_points=G, n_chunks=len(groups),
            wall_s=wall_b))
        if verbose:
            print(f"# serving bucket {kind}/R{n_rows}: {G} cell(s), "
                  f"T<={pad_t}, {len(bounds)} boundaries, {wall_b:.1f}s")

    points = [SweepPoint(
        protocol=c.label(), workload=c.workload, n_threads=c.n_threads,
        horizon=c.schedule.horizon, p_abort=c.p_abort, costs=c.costs,
        name=c.name, tag=c.schedule.name) for c in cells]
    return ServeResults(
        points=points, metrics=metrics, wall_us=wall_us, buckets=infos,
        n_compiles=_seg_compiles() - compiles0,
        wall_s=time.perf_counter() - t_start, segments=segments,
        serving=serving, states=states_out, responses=responses_out)
