"""Live serving metrics: a Prometheus-text-exposition registry.

The serving loop (:func:`repro.serving.runner.serve`) observes the open
system only at segment boundaries — that is the natural scrape cadence,
so the registry is updated per :class:`~repro.serving.runner.ServingRecord`
(pass ``metrics=ServingMetrics()`` to ``serve``) and rendered on demand in
the Prometheus text exposition format (version 0.0.4): ``# HELP`` /
``# TYPE`` headers, ``name{label="v"} value`` samples.

Design points (DESIGN.md §14):

* **Counters are cumulative and monotonic** — ``*_total`` families sum
  window deltas (arrived/rejected/shed/completed/sla_miss/commits), so a
  real Prometheus server scraping :func:`ServingMetrics.serve_http` at any
  cadence sees correct rates via ``rate()`` regardless of how boundary
  windows align with scrapes.
* **Gauges are last-window observations** — queue depth, in-flight,
  window percentiles, throughput, occupancy, and the SLA burn rate
  (window miss fraction / SLA budget, the standard error-budget-consumption
  dial; 1.0 = burning exactly the budget).
* **Hotspot gauges** surface the engine's per-record contention
  accumulator: the top-K rows of the window's ``hotspots`` ranking become
  ``repro_hotspot_wait_ticks{cell,rank,row}`` samples plus a
  ``repro_hotspot_top1_share`` concentration dial. Empty (no samples)
  when the cell runs with ``attrib=False`` — attribution stays opt-in.
* **No daemon required** — ``render()`` returns the exposition text,
  ``dump(path)`` writes it atomically (write-then-rename) for
  node-exporter-textfile-style collection, and ``serve_http(port)``
  starts a stdlib ThreadingHTTPServer for live scraping. Nothing here
  touches the device: every input is a host-side record the serving loop
  already produced.
"""
from __future__ import annotations

import http.server
import os
import threading
from typing import Iterable

__all__ = ["MetricFamily", "ServingMetrics", "render_families"]

_EXPO_VERSION = "0.0.4"


def _fmt_value(v: float) -> str:
    """Prometheus sample value: integers bare, floats via repr."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


class MetricFamily:
    """One named metric family: type + help + labelled samples.

    Samples are keyed by a sorted tuple of ``(label, value)`` pairs.
    Counters enforce monotonicity (``inc`` with a negative delta raises),
    gauges are free-set.
    """

    def __init__(self, name: str, kind: str, help_: str):
        assert kind in ("counter", "gauge"), kind
        self.name = name
        self.kind = kind
        self.help = help_
        self.samples: dict[tuple, float] = {}

    @staticmethod
    def _key(labels: dict) -> tuple:
        return tuple(sorted((str(k), str(v)) for k, v in labels.items()))

    def inc(self, value: float = 1.0, **labels) -> None:
        assert self.kind == "counter", self.name
        if value < 0:
            raise ValueError(
                f"counter {self.name} decremented by {value}")
        k = self._key(labels)
        self.samples[k] = self.samples.get(k, 0.0) + float(value)

    def set(self, value: float, **labels) -> None:
        assert self.kind == "gauge", self.name
        self.samples[self._key(labels)] = float(value)

    def clear(self, **label_subset) -> None:
        """Drop samples whose labels include ``label_subset`` (used to
        retire stale top-K hotspot ranks between windows)."""
        sub = set(self._key(label_subset))
        self.samples = {k: v for k, v in self.samples.items()
                        if not sub.issubset(set(k))}

    def get(self, **labels) -> float:
        return self.samples.get(self._key(labels), 0.0)

    def render(self) -> str:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self.samples):
            if key:
                lbl = ",".join(f'{k}="{_escape_label(v)}"'
                               for k, v in key)
                lines.append(
                    f"{self.name}{{{lbl}}} "
                    f"{_fmt_value(self.samples[key])}")
            else:
                lines.append(f"{self.name} "
                             f"{_fmt_value(self.samples[key])}")
        return "\n".join(lines)


def render_families(families: Iterable[MetricFamily]) -> str:
    """Full exposition text: families in declaration order, trailing \\n."""
    return "\n".join(f.render() for f in families) + "\n"


# (name, kind, help) — declaration order is exposition order
_FAMILIES = (
    ("repro_serving_arrivals_total", "counter",
     "Requests that arrived (admitted or refused)."),
    ("repro_serving_rejected_total", "counter",
     "Requests refused at the admission bound (policy=reject)."),
    ("repro_serving_shed_total", "counter",
     "Queued requests dropped to admit newer ones (policy=shed)."),
    ("repro_serving_completed_total", "counter",
     "Responses observed at boundaries (commits + user aborts)."),
    ("repro_serving_sla_miss_total", "counter",
     "Completions whose response time exceeded the cell SLA."),
    ("repro_serving_commits_total", "counter",
     "Engine transaction commits (goodput numerator)."),
    ("repro_serving_windows_total", "counter",
     "Boundary windows observed."),
    ("repro_serving_queue_depth", "gauge",
     "Admission queue length after dispatch at the last boundary."),
    ("repro_serving_in_flight", "gauge",
     "Dispatched-but-unfinished requests at the last boundary."),
    ("repro_serving_window_ticks", "gauge",
     "Simulated ticks covered by the last window."),
    ("repro_serving_throughput_tps", "gauge",
     "Engine commit throughput over the last window (txn/s)."),
    ("repro_serving_occupancy", "gauge",
     "Engine CPU utilization over the last window (0..1)."),
    ("repro_serving_lock_wait_frac", "gauge",
     "Fraction of thread-ticks spent in lock wait, last window."),
    ("repro_serving_p50_us", "gauge",
     "p50 response time of the last window's completions (us)."),
    ("repro_serving_p99_us", "gauge",
     "p99 response time of the last window's completions (us)."),
    ("repro_serving_p999_us", "gauge",
     "p99.9 response time of the last window's completions (us)."),
    ("repro_serving_sla_burn_rate", "gauge",
     "Window SLA-miss fraction divided by the SLA error budget "
     "(1.0 = consuming exactly the budget; 0 when no SLA/budget)."),
    ("repro_hotspot_wait_ticks", "gauge",
     "Lock-wait ticks charged to a top-K contended record, last window."),
    ("repro_hotspot_grants", "gauge",
     "Lock grants on a top-K contended record, last window."),
    ("repro_hotspot_queue_max", "gauge",
     "Peak global row-queue depth increase observed in the window."),
    ("repro_hotspot_top1_share", "gauge",
     "Top-1 record's share of the window's attributed wait ticks."),
)


class ServingMetrics:
    """Per-cell serving metrics registry (see module docstring).

    ``sla_budget`` is the tolerated SLA-miss fraction the burn rate is
    measured against (SRE convention: burn rate = observed miss fraction
    / budget). ``top_k`` bounds the hotspot gauge fan-out per cell.
    """

    def __init__(self, sla_budget: float = 0.001, top_k: int = 5):
        assert sla_budget > 0 and top_k >= 0
        self.sla_budget = float(sla_budget)
        self.top_k = int(top_k)
        self.families: dict[str, MetricFamily] = {
            name: MetricFamily(name, kind, help_)
            for name, kind, help_ in _FAMILIES}
        self._lock = threading.Lock()

    # -- update -----------------------------------------------------------
    def observe(self, cell_name: str, record) -> None:
        """Fold one boundary :class:`ServingRecord` into the registry."""
        f = self.families
        m = record.metrics
        window = max(1, record.t1 - record.t0)
        with self._lock:
            c = dict(cell=cell_name)
            f["repro_serving_arrivals_total"].inc(record.arrived, **c)
            f["repro_serving_rejected_total"].inc(record.rejected, **c)
            f["repro_serving_shed_total"].inc(record.shed, **c)
            f["repro_serving_completed_total"].inc(record.completed, **c)
            f["repro_serving_sla_miss_total"].inc(record.sla_miss, **c)
            f["repro_serving_commits_total"].inc(m.commits, **c)
            f["repro_serving_windows_total"].inc(1, **c)
            f["repro_serving_queue_depth"].set(record.qlen, **c)
            f["repro_serving_in_flight"].set(record.in_flight, **c)
            f["repro_serving_window_ticks"].set(window, **c)
            f["repro_serving_throughput_tps"].set(m.tps, **c)
            f["repro_serving_occupancy"].set(m.cpu_util, **c)
            f["repro_serving_lock_wait_frac"].set(m.lock_wait_frac, **c)
            f["repro_serving_p50_us"].set(record.p50_us, **c)
            f["repro_serving_p99_us"].set(record.p99_us, **c)
            f["repro_serving_p999_us"].set(record.p999_us, **c)
            miss_frac = (record.sla_miss / record.completed
                         if record.completed else 0.0)
            f["repro_serving_sla_burn_rate"].set(
                miss_frac / self.sla_budget, **c)
            self._observe_hotspots(cell_name, record)

    def _observe_hotspots(self, cell_name: str, record) -> None:
        """Top-K hotspot gauges from the window's ``hotspots`` ranking
        (empty when the cell runs attribution off). Ranks are re-set
        every window; stale higher ranks from a previous, busier window
        are cleared so the exposition never shows ghost rows."""
        f = self.families
        hot = list(getattr(record.metrics, "hotspots", []))[:self.top_k]
        for fam in ("repro_hotspot_wait_ticks", "repro_hotspot_grants"):
            f[fam].clear(cell=cell_name)
        total_wait = 0
        qmax = 0
        for rank, h in enumerate(hot):
            lbl = dict(cell=cell_name, rank=str(rank), row=str(h["row"]))
            f["repro_hotspot_wait_ticks"].set(h["wait_ticks"], **lbl)
            f["repro_hotspot_grants"].set(h["grants"], **lbl)
            qmax = max(qmax, int(h["queue_max"]))
        for h in getattr(record.metrics, "hotspots", []):
            total_wait += int(h["wait_ticks"])
        f["repro_hotspot_queue_max"].set(qmax, cell=cell_name)
        top1 = int(hot[0]["wait_ticks"]) if hot else 0
        f["repro_hotspot_top1_share"].set(
            top1 / total_wait if total_wait else 0.0, cell=cell_name)

    # -- read -------------------------------------------------------------
    def get(self, family: str, **labels) -> float:
        return self.families[family].get(**labels)

    def render(self) -> str:
        """The full Prometheus text exposition (version 0.0.4)."""
        with self._lock:
            return render_families(self.families.values())

    def dump(self, path) -> str:
        """Write the exposition atomically (textfile-collector style)."""
        text = self.render()
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            fh.write(text)
        os.replace(tmp, path)
        return text

    # -- scrape endpoint --------------------------------------------------
    def serve_http(self, port: int = 0, host: str = "127.0.0.1"):
        """Start a daemon-thread HTTP server exposing ``/metrics``.

        Returns the :class:`http.server.ThreadingHTTPServer`; read the
        bound port off ``server.server_address[1]`` (``port=0`` picks a
        free one) and stop it with ``server.shutdown()``.
        """
        registry = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):             # noqa: N802 (stdlib API)
                if self.path.split("?")[0] not in ("/", "/metrics"):
                    self.send_error(404)
                    return
                body = registry.render().encode()
                self.send_response(200)
                self.send_header(
                    "Content-Type",
                    f"text/plain; version={_EXPO_VERSION}; "
                    "charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):     # quiet by default
                pass

        server = http.server.ThreadingHTTPServer((host, port), Handler)
        threading.Thread(target=server.serve_forever,
                         daemon=True).start()
        return server
