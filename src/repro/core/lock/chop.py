"""Transaction chopping + SLW-graph lock-order analysis (Brook-2PL).

The static-analysis half of Brook-2PL ("Tolerating High Contention
Workloads with A Deadlock-Free Two-Phase Locking Protocol", Habibi et
al., PAPERS.md): instead of resolving deadlocks *dynamically* (waits-for
walks, timeouts, victim aborts — every prior protocol in ``engine.py``),
Brook-2PL makes them *structurally impossible* by analysing the
transaction templates of a workload ahead of time and emitting

1. a **global lock-acquisition order** — every transaction re-sorts its
   ops so rows are locked in one canonical order.  Along any waits-for
   edge the blocked op's rank is strictly greater than every rank the
   holder still holds (ops before the wait point are all lower-ranked,
   same-rank ops are the same key and therefore re-entrant), so
   waits-for cycles cannot close and no detection machinery is needed;
2. **per-op release points** — the last op touching each row class,
   after which the row's lock can retire (shrinking the 2PL hold
   interval to ``[acquire, last-use]`` instead of ``[acquire, commit]``).

Both artifacts are *data*, not code: the acquisition order ships as a
per-key rank table (``DynWorkload.acq_rank``, an ``(R,)`` i32 array
computed eagerly on the host exactly like the Zipf CDF) and the release
points are evaluated per transaction instance at generation time —
``gen_txn_dyn`` inlines the :func:`last_use` computation so it can share
the dup analysis's pairwise-equality tensor (:func:`last_use` here is
the standalone reference; tests/test_chop.py asserts the two agree) —
so vmapped sweep lanes and per-config runs consume bit-identical tables
and the whole protocol rides the existing ``DynParams`` flag substrate
(``ordered_acquire`` / ``per_op_release``).

The analysis pipeline over a :class:`~repro.core.lock.workload.WorkloadSpec`:

``row_classes``  — partition the key space into classes with a static
                   per-row *heat* (expected accesses per transaction per
                   row: the contention potential);
``txn_template`` — the per-op-slot (class, writes?) structure;
``slw_graph``    — the static-lock-wait graph: one node per op template,
                   a directed edge u -> v whenever a transaction can
                   *hold* u's lock while *waiting* for v's, weighted by
                   the product of the class heats (how often that hold-
                   while-wait materialises under contention);
``acquisition_order`` — the canonical class order minimising the total
                   SLW edge weight into hot classes: hot rows are
                   acquired **last**, so the span between a hot row's
                   lock point and its release point (its last use — for
                   a hot class ordered last, the very next op) is as
                   short as the chopping allows;
``acquisition_rank``  — the class order flattened to a per-key rank
                   permutation (ties broken by key id, deterministic);
``template_release_points`` — static may-alias release slots per op
                   template (exact per-instance last-use is computed by
                   :func:`last_use` on the generated keys).

``chop()`` bundles everything into a :class:`ChopPlan` for tests, docs,
and the quickstart's human-readable dump.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

I32 = jnp.int32

# sort-key sentinel pushing padded (inactive) op slots after every active
# one; active sort keys are rank * L + slot < 2**28 for every real grid
_PAD_KEY = np.int32(2 ** 29)


def zipf_weights(n: int, s: float) -> np.ndarray:
    """Unnormalized Zipf(s) weights over ranks 1..n (float64).

    THE single definition of the engine's Zipf distribution: the
    workload CDF (``workload.zipf_cdf`` = normalized cumsum, drives key
    generation) and the chop heat model (normalized pmf, drives the
    acquisition rank) both derive from it, so the "hottest keys locked
    last" property can never silently diverge from the keys actually
    drawn.
    """
    ranks = np.arange(1, n + 1, dtype=np.float64)
    return ranks ** (-float(s)) if s > 0 else np.ones_like(ranks)


# ---------------------------------------------------------------------------
# row classes and op templates (static, per workload kind)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RowClass:
    """A key-space partition with uniform static contention potential.

    ``heat`` is the expected number of accesses per transaction landing
    on ONE row of the class (class access probability / class size) —
    the quantity the SLW ordering minimises lock hold time for. ``lo``/
    ``hi`` bound the class's key range before any ``hot_base`` rotation.
    """
    name: str
    lo: int
    hi: int
    heat: float

    @property
    def size(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class OpTemplate:
    """One op slot of a transaction template: which class, lock taken?"""
    slot: int
    cls: str
    wr: bool


def row_classes(spec) -> list[RowClass]:
    """Partition ``spec``'s key space into heat-annotated row classes."""
    R, L = spec.n_rows, spec.txn_len
    kind = spec.kind
    if kind == "hotspot_update":
        # op 0 always writes THE hot row; L-1 ops spread over the rest
        return [RowClass("hot", 0, 1, 1.0),
                RowClass("rest", 1, R, (L - 1) / max(R - 1, 1))]
    if kind in ("zipf", "hotspot_mix"):
        # graded-heat class: per-key heat comes from the Zipf pmf (see
        # _key_heat); the class-level heat is the hottest rank's mass
        w = zipf_weights(R, spec.zipf_s)
        return [RowClass("zipf", 0, R, float(L * w[0] / w.sum()))]
    if kind == "hotspot_scan":
        warm = min(max(int(spec.n_hot) * 16, 2), R)
        return [RowClass("warm", 0, warm, L / warm),
                RowClass("cold", warm, R, 0.0)]
    if kind == "uniform":
        return [RowClass("uniform", 0, R, L / R)]
    if kind == "fit":
        nh = min(max(int(spec.n_hot), 1), R)
        return [RowClass("hot_account", 0, nh, 1.0 / nh),
                RowClass("record", nh, R,
                         max(L - 1, 1) / max(R - nh, 1))]
    if kind == "tpcc":
        W = max(int(spec.n_warehouses), 1)
        return [RowClass("warehouse", 0, W, 1.0 / W),
                RowClass("district", W, 11 * W, 1.0 / (10 * W)),
                RowClass("stock", 11 * W, R,
                         max(L - 2, 0) * spec.write_ratio
                         / max(R - 11 * W, 1))]
    raise ValueError(f"chop: unknown workload kind {kind!r}")


def txn_template(spec) -> list[OpTemplate]:
    """The op-slot structure of ``spec``'s transaction template."""
    L, kind = spec.txn_len, spec.kind
    wr = spec.write_ratio > 0 or spec.reads_lock
    if kind == "hotspot_update":
        return [OpTemplate(0, "hot", True)] + [
            OpTemplate(l, "rest", wr) for l in range(1, L)]
    if kind in ("zipf", "hotspot_mix"):
        w = kind == "zipf" or wr
        return [OpTemplate(l, "zipf", w) for l in range(L)]
    if kind == "hotspot_scan":
        return [OpTemplate(l, "warm", True) for l in range(L)]
    if kind == "uniform":
        return [OpTemplate(l, "uniform", wr) for l in range(L)]
    if kind == "fit":
        return ([OpTemplate(0, "hot_account", True)]
                + [OpTemplate(l, "record", l == 1 or wr)
                   for l in range(1, L)])
    if kind == "tpcc":
        return ([OpTemplate(0, "warehouse", True),
                 OpTemplate(1, "district", True)][:L]
                + [OpTemplate(l, "stock", wr) for l in range(2, L)])
    raise ValueError(f"chop: unknown workload kind {kind!r}")


# ---------------------------------------------------------------------------
# SLW graph and the canonical acquisition order
# ---------------------------------------------------------------------------

def slw_graph(spec) -> dict[tuple[str, str], float]:
    """Static-lock-wait graph over ``spec``'s op templates.

    Edge ``(a, b) -> weight``: a transaction can hold a lock of class
    ``a`` while waiting for one of class ``b`` (``a`` locked at an
    earlier slot than ``b`` in the template's *current* program order),
    weighted by ``heat_a * heat_b`` — the static stand-in for how often
    two concurrent transactions actually collide on that hold-while-wait
    pattern. Re-sorting acquisition so hot classes come last moves the
    heavy edges to point *at* the hottest class from everywhere, which
    is exactly the configuration in which the hot lock's hold interval
    ``[acquire, last-use]`` is shortest.
    """
    heat = {c.name: c.heat for c in row_classes(spec)}
    edges: dict[tuple[str, str], float] = {}
    tmpl = [t for t in txn_template(spec) if t.wr]
    for i, u in enumerate(tmpl):
        for v in tmpl[i + 1:]:
            if u.cls == v.cls:
                continue            # same class = re-entrant, no wait
            k = (u.cls, v.cls)
            edges[k] = edges.get(k, 0.0) + heat[u.cls] * heat[v.cls]
    return edges


def acquisition_order(spec) -> list[str]:
    """Canonical class acquisition order: ascending heat, hot last.

    This is the order minimising the summed SLW weight held *across*
    each wait (for the single-template workloads here the minimiser of
    sum-of-heat-held-while-waiting is exactly ascending heat; asserting
    totality keeps the rank table a permutation). Deterministic: heat
    ties break on the class name.
    """
    classes = row_classes(spec)
    order = sorted(classes, key=lambda c: (c.heat, c.name))
    assert len({c.name for c in order}) == len(order)
    return [c.name for c in order]


def _key_heat(spec) -> np.ndarray:
    """(R,) float64 per-key heat (expected accesses/txn), host-side.

    The ``hot_base`` rotation mirrors ``gen_txn_dyn`` per kind exactly:
    only the hot set relocates — zipf kinds rotate the whole profile,
    hotspot_update moves THE hot row, fit/hotspot_scan move the hot/warm
    window while the uniform remainder keys stay where the generator
    draws them (unrotated)."""
    R = spec.n_rows
    heat = np.zeros(R, np.float64)
    hb = int(spec.hot_base) % R
    classes = {c.name: c for c in row_classes(spec)}
    if spec.kind in ("zipf", "hotspot_mix"):
        # zipf rank j sits AT key (hot_base + j) % R (workload.py rotates
        # the whole skew profile by hot_base)
        w = zipf_weights(R, spec.zipf_s)
        pmf = spec.txn_len * w / w.sum()
        heat[(hb + np.arange(R)) % R] = pmf
    elif spec.kind == "hotspot_update":
        # rest keys draw from [1, R) with the hot key dodge-swapped to 0
        heat[:] = classes["rest"].heat
        heat[hb] = classes["hot"].heat
    elif spec.kind == "hotspot_scan":
        warm = classes["warm"]
        heat[(np.arange(warm.lo, warm.hi) + hb) % R] = warm.heat
    elif spec.kind == "fit":
        # record inserts draw unrotated from [n_hot, R); the hot account
        # window rotates and may overlap them (drift's point) — max wins
        rec, hot = classes["record"], classes["hot_account"]
        heat[rec.lo:rec.hi] = rec.heat
        idx = (np.arange(hot.lo, hot.hi) + hb) % R
        heat[idx] = np.maximum(heat[idx], hot.heat)
    else:                       # uniform / tpcc: no hot_base semantics
        for c in classes.values():
            heat[c.lo:c.hi] = c.heat
    return heat


def acquisition_rank(spec) -> jnp.ndarray:
    """Per-key canonical lock-acquisition rank, (R,) i32 on device.

    ``rank`` is a permutation of ``[0, R)``: transactions under
    ``ordered_acquire`` lock their rows in ascending rank, so the
    hottest keys (highest heat) are locked last and held shortest.
    Eager host-side numpy (like ``zipf_cdf_table``) so every consumer —
    per-config run, vmapped sweep lane, governed segment — sees a
    bit-identical table.
    """
    heat = _key_heat(spec)
    order = np.lexsort((np.arange(spec.n_rows), heat))   # heat asc, key asc
    rank = np.empty(spec.n_rows, np.int32)
    rank[order] = np.arange(spec.n_rows, dtype=np.int32)
    return jnp.asarray(rank)


def template_release_points(spec) -> list[int]:
    """Static per-slot release points: last slot that MAY touch the same
    rows (class-level may-alias). The engine refines this to the exact
    per-instance last use (:func:`last_use`); the template view is what
    the chopping argument reasons over — a slot whose class never recurs
    releases at itself, re-capturable classes release at their last
    occurrence."""
    tmpl = txn_template(spec)
    return [max(v.slot for v in tmpl if v.cls == u.cls) for u in tmpl]


@dataclasses.dataclass(frozen=True)
class ChopPlan:
    """The full static analysis of one workload (tests, docs, dumps)."""
    kind: str
    classes: tuple          # RowClass...
    template: tuple         # OpTemplate...
    slw: tuple              # ((cls_a, cls_b, weight), ...) sorted desc
    order: tuple            # canonical class acquisition order
    release: tuple          # per-template-slot release points

    def describe(self) -> str:
        lines = [f"chop[{self.kind}]"]
        lines.append("  classes: " + ", ".join(
            f"{c.name}[{c.lo}:{c.hi}) heat={c.heat:.2e}"
            for c in self.classes))
        lines.append("  template: " + " -> ".join(
            f"{t.cls}{'(w)' if t.wr else '(r)'}" for t in self.template))
        lines.append("  slw: " + (", ".join(
            f"{a}->{b}:{w:.1e}" for a, b, w in self.slw) or "(none)"))
        lines.append("  acquire order: " + " < ".join(self.order))
        lines.append(f"  release points: {list(self.release)}")
        return "\n".join(lines)


def chop(spec) -> ChopPlan:
    """Run the whole pipeline over one workload spec."""
    edges = sorted(((a, b, w) for (a, b), w in slw_graph(spec).items()),
                   key=lambda e: -e[2])
    return ChopPlan(
        kind=spec.kind,
        classes=tuple(row_classes(spec)),
        template=tuple(txn_template(spec)),
        slw=tuple(edges),
        order=tuple(acquisition_order(spec)),
        release=tuple(template_release_points(spec)))


# ---------------------------------------------------------------------------
# traced helpers (consumed inside the engine step)
# ---------------------------------------------------------------------------

def apply_acquisition_order(rank: jnp.ndarray, keys: jnp.ndarray,
                            iswr: jnp.ndarray, txn_len: jnp.ndarray,
                            enabled: jnp.ndarray):
    """Re-sort each transaction's ACTIVE ops into canonical rank order.

    ``rank`` is the (R,) table from :func:`acquisition_rank`; ``keys`` /
    ``iswr`` are the (T, L) generated programs; ``txn_len`` (traced
    scalar) bounds the active slots — padded slots keep their positions
    after every active one, so padding stays bitwise invisible. The sort
    key ``rank * L + slot`` is collision-free (stability for free), and
    same-key ops stay in program order (same rank, ascending slot), so
    the dup/re-entrant analysis downstream sees the usual layout.
    ``enabled`` (traced bool — ``DynParams.ordered_acquire``) selects
    the sorted or original program, so one compiled step serves both.
    """
    T, L = keys.shape
    # shapes are static, so the sort-key bound is enforceable at trace
    # time: rank*L + slot must stay below the pad sentinel (and i32)
    assert rank.shape[0] * L < int(_PAD_KEY), (
        f"chop sort key overflow: n_rows*L = {rank.shape[0] * L} "
        f">= {int(_PAD_KEY)}; shrink the key space or raise _PAD_KEY")
    slot = jnp.arange(L, dtype=I32)[None, :]
    active = slot < txn_len
    skey = jnp.where(active, rank[keys] * I32(L) + slot, _PAD_KEY + slot)
    order = jnp.argsort(skey, axis=1)
    sk = jnp.take_along_axis(keys, order, axis=1)
    sw = jnp.take_along_axis(iswr, order, axis=1)
    return (jnp.where(enabled, sk, keys), jnp.where(enabled, sw, iswr))


def last_use(keys: jnp.ndarray, nops: jnp.ndarray) -> jnp.ndarray:
    """(T, L) bool: slot is the LAST active slot touching its key.

    The per-instance release points: when the op at a last-use slot
    completes, the key's ticket has no further use in the transaction
    and may retire (``per_op_release``). Exact, not may-alias — computed
    on the actual generated keys, traced, once per transaction start.

    Reference implementation: the engine consumes the equivalent plane
    ``gen_txn_dyn`` returns (inlined there to reuse the dup analysis's
    eq tensor); changing release semantics means changing BOTH, and
    tests/test_chop.py asserts they agree.
    """
    T, L = keys.shape
    slot = jnp.arange(L, dtype=I32)
    active = slot[None, :] < nops[:, None]                   # (T, L)
    eq = keys[:, :, None] == keys[:, None, :]                # (T, L, L)
    later = (slot[None, :] > slot[:, None])[None]            # (1, L, L)
    has_later = jnp.any(eq & later & active[:, None, :], axis=2)
    return active & ~has_later
