"""Aria baseline: deterministic batch OCC (Lu et al., VLDB'20).

Aria needs no tick loop: it executes fixed batches against a snapshot, then
runs a deterministic reservation check; conflict losers abort and rerun in
the next batch. One (group) commit per batch.

Reservation rules implemented (per the Aria paper, simplified to the
single-version counter rows of our engine):
  * WAW: a transaction aborts if any of its write keys is also written by a
    transaction with a smaller batch position (the reservation winner).
  * RAW: a transaction aborts if any of its read keys is written by a
    transaction with a smaller batch position.

With a single-hotspot workload every batch commits exactly one transaction
on the hot key — the flat-but-low TPS curve of the paper's Figure 8.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .costs import CostModel
from .workload import WorkloadSpec, gen_txn
from .engine import I32, F32, INF, N_HIST, _hist_bucket
from .metrics import SimResult, TICKS_PER_SEC

BARRIER = 50  # per-batch scheduling barrier (ticks)


class AriaState(NamedTuple):
    txn: jnp.ndarray        # (T,) per-lane txn counter
    retries: jnp.ndarray    # (T,) consecutive aborts of the current txn
    now: jnp.ndarray
    commits: jnp.ndarray
    aborts: jnp.ndarray
    lat_sum: jnp.ndarray
    hist: jnp.ndarray
    committed_val: jnp.ndarray  # (R,)


@dataclasses.dataclass(frozen=True)
class AriaConfig:
    workload: WorkloadSpec
    costs: CostModel
    n_threads: int
    horizon: int = 2_000_000


@functools.partial(jax.jit, static_argnums=0)
def _run(cfg: AriaConfig) -> AriaState:
    w, c, T = cfg.workload, cfg.costs, cfg.n_threads
    R, L = w.n_rows, w.txn_len
    tids = jnp.arange(T, dtype=I32)

    exec_time = L * c.op_exec + BARRIER
    batch_time = exec_time + c.commit_base + c.sync_lat

    def batch(s: AriaState) -> AriaState:
        keys, iswr, dup, _ = gen_txn(w, tids, s.txn)
        lane = jnp.broadcast_to(tids[:, None], (T, L))

        # reservations: smallest lane id wins each written key
        wr_res = jax.ops.segment_min(
            jnp.where(iswr, lane, INF).reshape(-1),
            keys.reshape(-1), num_segments=R)
        waw = (iswr & (wr_res[keys] < lane)).any(axis=1)
        raw = (~iswr & (wr_res[keys] < lane)).any(axis=1)
        abort = waw | raw
        commit = ~abort

        committed_val = s.committed_val + jax.ops.segment_sum(
            jnp.where(iswr & commit[:, None], 1, 0).reshape(-1),
            keys.reshape(-1), num_segments=R)

        now = s.now + batch_time
        lat = (s.retries + 1) * batch_time
        hist = s.hist.at[_hist_bucket(lat)].add(
            jnp.where(commit, 1, 0), mode="drop")
        return AriaState(
            txn=s.txn + jnp.where(commit, 1, 0),
            retries=jnp.where(commit, 0, s.retries + 1),
            now=now,
            commits=s.commits + commit.sum(),
            aborts=s.aborts + abort.sum(),
            lat_sum=s.lat_sum + jnp.where(commit, lat, 0).sum().astype(F32),
            hist=hist,
            committed_val=committed_val,
        )

    s0 = AriaState(
        txn=jnp.zeros((T,), I32), retries=jnp.zeros((T,), I32),
        now=jnp.asarray(0, I32), commits=jnp.asarray(0, I32),
        aborts=jnp.asarray(0, I32), lat_sum=jnp.asarray(0.0, F32),
        hist=jnp.zeros((N_HIST,), I32),
        committed_val=jnp.zeros((R,), I32),
    )
    return lax.while_loop(lambda s: s.now < cfg.horizon, batch, s0)


def simulate_aria(workload: WorkloadSpec, n_threads: int,
                  costs: CostModel | None = None,
                  horizon: int = 2_000_000) -> AriaState:
    return _run(AriaConfig(workload, costs or CostModel(),
                           n_threads, horizon))


def extract_aria(n_threads: int, s: AriaState) -> SimResult:
    import numpy as np
    from .metrics import _pct_from_hist
    commits = int(s.commits)
    aborts = int(s.aborts)
    now = max(int(s.now), 1)
    sim_s = now / TICKS_PER_SEC
    return SimResult(
        protocol="aria", n_threads=n_threads, commits=commits,
        user_aborts=0, forced_aborts=aborts, lock_ops=0,
        sim_seconds=sim_s, tps=commits / sim_s,
        mean_latency_us=(float(s.lat_sum) / commits / 10.0) if commits else 0,
        p95_latency_us=_pct_from_hist(np.asarray(s.hist), 0.95),
        p99_latency_us=_pct_from_hist(np.asarray(s.hist), 0.99),
        lock_wait_frac=0.0, cpu_util=1.0,
        abort_rate=aborts / max(commits + aborts, 1),
        iters=0,
    )
