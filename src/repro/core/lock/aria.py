"""Aria baseline: deterministic batch OCC (Lu et al., VLDB'20).

Aria needs no tick loop: it executes fixed batches against a snapshot, then
runs a deterministic reservation check; conflict losers abort and rerun in
the next batch. One (group) commit per batch.

Reservation rules implemented (per the Aria paper, simplified to the
single-version counter rows of our engine):
  * WAW: a transaction aborts if any of its write keys is also written by a
    transaction with a smaller batch position (the reservation winner).
  * RAW: a transaction aborts if any of its read keys is written by a
    transaction with a smaller batch position.

With a single-hotspot workload every batch commits exactly one transaction
on the hot key — the flat-but-low TPS curve of the paper's Figure 8.

Like the tick engine, all value-like parameters (costs, horizon, workload
params, active thread count) are traced (:class:`AriaDyn`), so the sweep
subsystem batches many Aria configs under ``jax.vmap`` with one compile per
(kind, T, L, R) shape; padded lanes (tid >= n_active) generate transactions
but are masked out of reservations, commits, and metrics.

Segmented execution (``_run_seg_dyn`` / ``_run_seg_batch``) resumes an
:class:`AriaState` and pauses once ``now`` reaches a traced ``until``;
batches are never split, so any segmentation replays the identical batch
sequence (bit-exact in every leaf). Each loop iteration advances ``now``
by exactly :func:`batch_ticks`, which the sweep compaction scheduler uses
to turn per-call iteration budgets into per-lane pause targets.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .costs import CostModel
from .workload import WorkloadSpec, DynWorkload, dyn_workload, gen_txn_dyn
from .engine import I32, F32, INF, N_HIST, StaticShape, _hist_bucket
from .metrics import SimResult, TICKS_PER_SEC

BARRIER = 50  # per-batch scheduling barrier (ticks)


class AriaState(NamedTuple):
    txn: jnp.ndarray        # (T,) per-lane txn counter
    retries: jnp.ndarray    # (T,) consecutive aborts of the current txn
    now: jnp.ndarray
    commits: jnp.ndarray
    aborts: jnp.ndarray
    lat_sum: jnp.ndarray
    hist: jnp.ndarray
    committed_val: jnp.ndarray  # (R,)


class AriaMetrics(NamedTuple):
    """The leaves extract_aria reads — a cheap device_get view."""
    now: jnp.ndarray
    commits: jnp.ndarray
    aborts: jnp.ndarray
    lat_sum: jnp.ndarray
    hist: jnp.ndarray


def metrics_view(s: AriaState) -> AriaMetrics:
    return AriaMetrics(now=s.now, commits=s.commits, aborts=s.aborts,
                       lat_sum=s.lat_sum, hist=s.hist)


@dataclasses.dataclass(frozen=True)
class AriaConfig:
    workload: WorkloadSpec
    costs: CostModel
    n_threads: int
    horizon: int = 2_000_000


class AriaDyn(NamedTuple):
    """Traced Aria parameters (one vmap lane each in a sweep)."""
    op_exec: jnp.ndarray
    commit_base: jnp.ndarray
    sync_lat: jnp.ndarray
    horizon: jnp.ndarray
    n_active: jnp.ndarray
    wl: DynWorkload


def split_aria(cfg: AriaConfig, pad_threads: int | None = None,
               pad_len: int | None = None) -> tuple[StaticShape, AriaDyn]:
    w, c = cfg.workload, cfg.costs
    T = pad_threads or cfg.n_threads
    L = pad_len or w.txn_len
    assert T >= cfg.n_threads and L >= w.txn_len
    stat = StaticShape(kind=w.kind, n_threads=T, txn_len=L, n_rows=w.n_rows)
    dp = AriaDyn(
        op_exec=jnp.asarray(c.op_exec, I32),
        commit_base=jnp.asarray(c.commit_base, I32),
        sync_lat=jnp.asarray(c.sync_lat, I32),
        horizon=jnp.asarray(cfg.horizon, I32),
        n_active=jnp.asarray(cfg.n_threads, I32),
        wl=dyn_workload(w),
    )
    return stat, dp


def init_aria_state(stat: StaticShape) -> AriaState:
    T, R = stat.n_threads, stat.n_rows
    return AriaState(
        txn=jnp.zeros((T,), I32), retries=jnp.zeros((T,), I32),
        now=jnp.asarray(0, I32), commits=jnp.asarray(0, I32),
        aborts=jnp.asarray(0, I32), lat_sum=jnp.asarray(0.0, F32),
        hist=jnp.zeros((N_HIST,), I32),
        committed_val=jnp.zeros((R,), I32),
    )


def batch_ticks(workload: WorkloadSpec, costs: CostModel) -> int:
    """Host-side mirror of the per-batch sim-time advance (``batch_time``
    in :func:`_make_batch`): every Aria loop iteration moves ``now`` by
    exactly this many ticks, so sim-time windows convert to iteration
    counts — the compaction scheduler uses it to size pause targets."""
    return (workload.txn_len * costs.op_exec + BARRIER
            + costs.commit_base + costs.sync_lat)


def _make_batch(stat: StaticShape, dp: AriaDyn):
    """Build the per-batch step function (shared by the single-shot and
    segmented loops, so segmented runs replay the identical batch
    sequence)."""
    T, R, L = stat.n_threads, stat.n_rows, stat.txn_len
    tids = jnp.arange(T, dtype=I32)
    active = tids < dp.n_active

    # active (not padded) txn length sets the batch execution time
    exec_time = dp.wl.txn_len * dp.op_exec + BARRIER
    batch_time = exec_time + dp.commit_base + dp.sync_lat

    # padded lanes (rows) and padded op slots (cols) reserve/read nothing
    slot_ok = jnp.arange(L, dtype=I32)[None, :] < dp.wl.txn_len

    def batch(s: AriaState) -> AriaState:
        keys, iswr, dup, _, _ = gen_txn_dyn(stat.kind, R, L, dp.wl, tids,
                                            s.txn)
        lane = jnp.broadcast_to(tids[:, None], (T, L))
        live = active[:, None] & slot_ok
        iswr = iswr & live

        # reservations: smallest lane id wins each written key
        wr_res = jax.ops.segment_min(
            jnp.where(iswr, lane, INF).reshape(-1),
            keys.reshape(-1), num_segments=R)
        waw = (iswr & (wr_res[keys] < lane)).any(axis=1)
        raw = (~iswr & live & (wr_res[keys] < lane)).any(axis=1)
        abort = waw | raw
        commit = ~abort & active

        committed_val = s.committed_val + jax.ops.segment_sum(
            jnp.where(iswr & commit[:, None], 1, 0).reshape(-1),
            keys.reshape(-1), num_segments=R)

        now = s.now + batch_time
        lat = (s.retries + 1) * batch_time
        hist = s.hist.at[_hist_bucket(lat)].add(
            jnp.where(commit, 1, 0), mode="drop")
        return AriaState(
            txn=s.txn + jnp.where(commit, 1, 0),
            retries=jnp.where(commit, 0, s.retries + 1),
            now=now,
            commits=s.commits + commit.sum(),
            aborts=s.aborts + abort.sum(),
            lat_sum=s.lat_sum + jnp.where(commit, lat, 0).sum().astype(F32),
            hist=hist,
            committed_val=committed_val,
        )

    return batch


def _run_core(stat: StaticShape, dp: AriaDyn) -> AriaState:
    return lax.while_loop(lambda s: s.now < dp.horizon,
                          _make_batch(stat, dp), init_aria_state(stat))


def _run_seg_core(stat: StaticShape, dp: AriaDyn, s0: AriaState,
                  until: jnp.ndarray) -> AriaState:
    """Resume ``s0`` and run whole batches until ``now`` reaches ``until``
    (or the horizon). Batches are never split — each loop iteration is one
    complete batch — so a run segmented at ANY boundaries executes the
    identical batch sequence and finishes bit-identical to the single-shot
    run in every leaf (Aria has no idle jumps to cap)."""
    return lax.while_loop(
        lambda s: (s.now < dp.horizon) & (s.now < until),
        _make_batch(stat, dp), s0)


@functools.partial(jax.jit, static_argnums=0)
def _run_dyn(stat: StaticShape, dp: AriaDyn) -> AriaState:
    return _run_core(stat, dp)


@functools.partial(jax.jit, static_argnums=0)
def _run_batch(stat: StaticShape, dps: AriaDyn) -> AriaState:
    """Run G stacked Aria configs as one vmapped program."""
    return jax.vmap(lambda dp: _run_core(stat, dp))(dps)


@functools.partial(jax.jit, static_argnums=0)
def _run_seg_dyn(stat: StaticShape, dp: AriaDyn, s0: AriaState,
                 until: jnp.ndarray) -> AriaState:
    return _run_seg_core(stat, dp, s0, until)


@functools.partial(jax.jit, static_argnums=0)
def _run_seg_batch(stat: StaticShape, dps: AriaDyn, s0s: AriaState,
                   untils: jnp.ndarray) -> AriaState:
    """Segmented analogue of :func:`_run_batch`: G resumable lanes, one
    program. The sweep compaction scheduler drives this with per-lane
    pause targets (``now + k * batch_ticks``) so heterogeneous-cost lanes
    retire at staggered calls and freed slots can be repacked."""
    return jax.vmap(
        lambda dp, s0, u: _run_seg_core(stat, dp, s0, u))(dps, s0s, untils)


def simulate_aria(workload: WorkloadSpec, n_threads: int,
                  costs: CostModel | None = None,
                  horizon: int = 2_000_000) -> AriaState:
    stat, dp = split_aria(AriaConfig(workload, costs or CostModel(),
                                     n_threads, horizon))
    return _run_dyn(stat, dp)


def extract_aria(n_threads: int, s: AriaState) -> SimResult:
    import numpy as np
    from .metrics import _pct_from_hist
    commits = int(s.commits)
    aborts = int(s.aborts)
    now = max(int(s.now), 1)
    sim_s = now / TICKS_PER_SEC
    return SimResult(
        protocol="aria", n_threads=n_threads, commits=commits,
        user_aborts=0, forced_aborts=aborts, lock_ops=0,
        sim_seconds=sim_s, tps=commits / sim_s,
        mean_latency_us=(float(s.lat_sum) / commits / 10.0) if commits else 0,
        p95_latency_us=_pct_from_hist(np.asarray(s.hist), 0.95),
        p99_latency_us=_pct_from_hist(np.asarray(s.hist), 0.99),
        lock_wait_frac=0.0, cpu_util=1.0,
        abort_rate=aborts / max(commits + aborts, 1),
        iters=0,
    )
