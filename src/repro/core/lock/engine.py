"""Vectorized discrete-time concurrency-control engine (the paper's core).

The engine simulates T database worker threads executing transactions over R
rows under one of five locking protocols (MySQL-2PL, O1 lightweight, O2
queue locking, TXSQL group locking, Bamboo), tick-accurately, entirely as a
compiled JAX program (``lax.while_loop`` over simulated time; all state in
arrays). Aria lives in ``aria.py`` (its batch structure needs no tick loop).

Modeling choices (see DESIGN.md §2.1):

* Every row's lock wait queue is a **ticket queue**: ``nt[r]`` is the next
  ticket; a thread takes a ticket when it reaches a write op. Queue/grant
  order is ticket order (FIFO, as in lock_sys / hot_row_hash).
* The grant rule is the protocol: strict-2PL rows grant ticket k when every
  ticket < k has *committed* (released); early-release rows (group-locking
  hot rows; every row under Bamboo) grant when every ticket < k has
  *applied its update* (Fig. 3).
* Rather than maintaining mutable queues, per-row aggregates (``us`` = next
  grantable ticket, ``cc`` = lowest uncommitted applied ticket = commit
  cursor, ``top`` = highest applied ticket, holder, queue length) are
  **re-derived every iteration from the per-thread ticket table** with
  segment reductions. Aborts simply clear ticket slots; order invariants
  are restored declaratively, which makes cascades and timeouts robust.
* The dependency list of the paper is exactly the ticket order of applied
  updates: commit requires ``cc[row] == my_ticket`` (commit order = update
  order, Alg. 2); cascades roll back from ``top`` downward (Alg. 3).
* Costs are integer ticks (0.1us); see ``costs.py`` for where each cost
  lands and why (deadlock-detection on the grant path reproduces Fig. 2a).

The per-row value is modeled as a counter: every applied write is +1 and
every rollback is -1, so serializability is *checkable*: at quiescence the
counter must equal the number of committed writes (no lost updates, no
dirty leftovers) — see tests/test_lock_properties.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .costs import CostModel, ProtocolParams, protocol_params
from .workload import WorkloadSpec, gen_txn, will_abort

I32 = jnp.int32
F32 = jnp.float32
INF = jnp.int32(2**30)
NOTK = jnp.int32(-1)          # "no ticket"
N_HIST = 64
HIST_BASE = 1.3

# thread phases
START, WAIT, EXEC, CWAIT, COMMIT, RBACK, RBWAIT, BACKOFF, ARRIVE, HALT = \
    range(10)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    protocol: ProtocolParams
    costs: CostModel
    workload: WorkloadSpec
    n_threads: int = 64
    horizon: int = 2_000_000          # ticks (0.1us) => 0.2s simulated
    p_abort: float = 0.0              # injected commit-time aborts (Fig 10)
    drain: bool = False               # run until all threads quiesce
    max_iters: int = 1_500_000
    seed: int = 0


class Threads(NamedTuple):
    phase: jnp.ndarray      # (T,)
    work: jnp.ndarray       # (T,) remaining ticks in paying phase
    op: jnp.ndarray         # (T,) current op slot
    txn: jnp.ndarray        # (T,) txn counter
    tstart: jnp.ndarray     # (T,) first-attempt start tick
    wstart: jnp.ndarray     # (T,) wait start tick
    willab: jnp.ndarray     # (T,) bool: injected abort at commit
    forced: jnp.ndarray     # (T,) bool: forced abort pending
    vabort: jnp.ndarray     # (T,) bool: abort is voluntary (move to next txn)
    retry: jnp.ndarray      # (T,) bool: current txn is a retry
    keys: jnp.ndarray       # (T, L)
    iswr: jnp.ndarray       # (T, L) bool
    dup: jnp.ndarray        # (T, L) bool
    ticket: jnp.ndarray     # (T, L) ticket or -1
    applied: jnp.ndarray    # (T, L) bool
    early: jnp.ndarray      # (T, L) bool: early-release semantics at apply
    committing: jnp.ndarray  # (T, L) bool: entered the commit queue
    nops: jnp.ndarray       # (T,)


class Rows(NamedTuple):
    nt: jnp.ndarray         # (R,) next ticket
    updating: jnp.ndarray   # (R,) bool: an update is executing
    hot: jnp.ndarray        # (R,) bool
    gleader: jnp.ndarray    # (R,) leader ticket of OPEN group, -1 if closed
    gcount: jnp.ndarray     # (R,) members granted in open group
    casc: jnp.ndarray       # (R,) cascade low ticket (INF = none)
    batch_end: jnp.ndarray  # (R,) group-commit batch completion tick
    batch_n: jnp.ndarray    # (R,) members in the open commit batch
    applied_val: jnp.ndarray    # (R,) net applied increments
    committed_val: jnp.ndarray  # (R,) committed increments


class Globals(NamedTuple):
    now: jnp.ndarray
    commits: jnp.ndarray
    user_aborts: jnp.ndarray
    forced_aborts: jnp.ndarray
    lock_ops: jnp.ndarray
    wait_ticks: jnp.ndarray     # f32 (lock-wait thread-ticks)
    busy_ticks: jnp.ndarray     # f32 (executing/committing thread-ticks)
    lat_sum: jnp.ndarray        # f32
    hist: jnp.ndarray           # (N_HIST,) i32 latency histogram
    iters: jnp.ndarray


class SimState(NamedTuple):
    th: Threads
    rows: Rows
    g: Globals


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _seg_min(data, segs, R, valid):
    data = jnp.where(valid, data, INF)
    return jax.ops.segment_min(data.reshape(-1), segs.reshape(-1),
                               num_segments=R)


def _seg_max(data, segs, R, valid):
    data = jnp.where(valid, data, -1)
    return jax.ops.segment_max(data.reshape(-1), segs.reshape(-1),
                               num_segments=R)


def _seg_sum(data, segs, R, valid):
    data = jnp.where(valid, data, 0)
    return jax.ops.segment_sum(data.reshape(-1), segs.reshape(-1),
                               num_segments=R)


def _hist_bucket(lat):
    b = jnp.log(lat.astype(F32) + 1.0) / np.log(HIST_BASE)
    return jnp.clip(b.astype(I32), 0, N_HIST - 1)


class Derived(NamedTuple):
    us: jnp.ndarray           # (R,) next grantable ticket
    cc: jnp.ndarray           # (R,) commit cursor (lowest uncommitted applied)
    top: jnp.ndarray          # (R,) highest applied ticket (-1 none)
    holder: jnp.ndarray       # (R,) thread holding lowest live ticket (-1)
    n_wait: jnp.ndarray       # (R,) unapplied live tickets (queue length)
    n_live: jnp.ndarray       # (R,) all live tickets
    hotof: jnp.ndarray        # (T,) row of first early applied op (-1)
    napp: jnp.ndarray         # (T,) applied op count per thread


def _derive(cfg: EngineConfig, th: Threads, rows: Rows) -> Derived:
    R = cfg.workload.n_rows
    p = cfg.protocol
    T, L = th.keys.shape
    live = th.ticket >= 0                                    # (T, L)
    keyf = th.keys

    # A slot's semantics are frozen when applied (th.early); a slot blocks
    # successors' updates unless it applied under early-release semantics.
    blocking = live & (~th.applied | ~th.early)
    us = _seg_min(th.ticket, keyf, R, blocking)
    us = jnp.where(us == INF, rows.nt, us)

    appl = live & th.applied
    # Commit cursor: with group commit, entering the commit queue releases
    # the *order* dependency (the batch syncs together, Fig. 5c); without
    # it, the dependency holds until the commit completes (slot cleared).
    cc_block = appl & (~th.committing if p.group_commit else
                       jnp.ones_like(appl))
    cc = _seg_min(th.ticket, keyf, R, cc_block)
    cc = jnp.where(cc == INF, us, cc)
    top = _seg_max(th.ticket, keyf, R, appl & ~th.committing)

    tid = jnp.broadcast_to(jnp.arange(T, dtype=I32)[:, None], (T, L))
    enc = th.ticket * I32(T) + tid
    hmin = _seg_min(enc, keyf, R, live)
    holder = jnp.where(hmin == INF, NOTK, hmin % I32(T))

    n_wait = _seg_sum(jnp.ones_like(th.ticket), keyf, R, live & ~th.applied)
    n_live = _seg_sum(jnp.ones_like(th.ticket), keyf, R, live)

    ea = appl & th.early                                     # (T, L)
    first = jnp.argmax(ea, axis=1)
    hotof = jnp.where(ea.any(axis=1),
                      keyf[jnp.arange(T), first], NOTK)
    napp = appl.sum(axis=1).astype(I32)
    return Derived(us, cc, top, holder, n_wait, n_live, hotof, napp)


# ---------------------------------------------------------------------------
# engine step
# ---------------------------------------------------------------------------

def _make_step(cfg: EngineConfig):
    p = cfg.protocol
    c = cfg.costs
    w = cfg.workload
    T = cfg.n_threads
    R = w.n_rows
    L = w.txn_len
    tids = jnp.arange(T, dtype=I32)

    # drain gets enough wall-clock past the horizon for timeouts to fire
    # and cascades to unwind (livelocks then surface as drain failures)
    stop_time = (cfg.horizon + 3 * max(p.wait_timeout, cfg.horizon)
                 if cfg.drain else cfg.horizon)

    def cur(field_tl, oph):
        """Gather per-thread value at its current op slot (clipped)."""
        return field_tl[tids, jnp.clip(oph, 0, L - 1)]

    def step(s: SimState) -> SimState:
        th, rows, g = s
        d = _derive(cfg, th, rows)
        now = g.now

        cur_key = cur(th.keys, th.op)
        cur_tkt = cur(th.ticket, th.op)
        in_wait = th.phase == WAIT

        # ------------------------------------------------ 1. mark aborts
        forced = th.forced
        # 1a. wait timeout
        if p.wait_timeout > 0:
            to = in_wait & ((now - th.wstart) >= p.wait_timeout)
            to |= (th.phase == CWAIT) & (
                (now - th.wstart) >= p.commit_wait_timeout)
            forced = forced | to
        # 1b. deadlock detection (waits-for cycle walk, up to 8 hops),
        # 2PL-style protocols. One victim per cycle: its max thread id.
        if p.has_detection:
            succ = jnp.where(in_wait, d.holder[cur_key], NOTK)
            succ = jnp.where(succ == tids, NOTK, succ)   # self-wait: none
            walk = succ
            mx = tids
            on_cycle = jnp.zeros_like(in_wait)
            for _ in range(8):
                ok = walk >= 0
                wi = jnp.where(ok, walk, 0)
                mx = jnp.maximum(mx, jnp.where(ok, walk, -1))
                on_cycle = on_cycle | (ok & (walk == tids))
                # follow only through threads that are themselves waiting
                walk = jnp.where(ok & (th.phase[wi] == WAIT),
                                 succ[wi], NOTK)
            victim = on_cycle & (tids == mx)
            forced = forced | victim
        # 1c. proactive hot+non-hot rollback (§4.5)
        if p.proactive_abort:
            hrow = d.hotof
            hold = d.holder[cur_key]
            hold_ok = hold >= 0
            hold_i = jnp.where(hold_ok, hold, 0)
            pro = (in_wait & (hrow >= 0) & hold_ok
                   & ~rows.hot[cur_key]
                   & (d.hotof[hold_i] == hrow) & (hold != tids))
            forced = forced | pro
        # 1d. cascade propagation: any applied early ticket >= casc[key]
        casc_at = rows.casc[th.keys]                          # (T, L)
        hit = (th.applied & th.early & (th.ticket >= 0)
               & (th.ticket >= casc_at))
        forced = forced | hit.any(axis=1)
        # threads that cannot abort anymore (committing) stay
        forced = forced & (th.phase != COMMIT) & (th.phase != HALT)

        # forced threads with applied early tickets keep cascades open
        # (idempotent marking — covers voluntary commit-point aborts too,
        # which become forced outside this stage)
        casc_src = (th.applied & th.early & (th.ticket >= 0)
                    & forced[:, None])
        casc_min = _seg_min(th.ticket, th.keys, R, casc_src)
        casc = jnp.minimum(rows.casc, casc_min)
        # clear finished cascades: no applied ticket at/above casc remains
        casc = jnp.where((casc < INF) & (d.top < casc), INF, casc)
        rows = rows._replace(casc=casc)
        th = th._replace(forced=forced)

        # ------------------------------------------------ 2. divert to RBWAIT
        # forced threads in WAIT/CWAIT park for their cascade turn.
        parkable = forced & ((th.phase == WAIT) | (th.phase == CWAIT))
        phase = jnp.where(parkable, RBWAIT, th.phase)
        th = th._replace(phase=phase,
                         wstart=jnp.where(parkable, now, th.wstart))

        # ------------------------------------------------ 4. grants
        d2 = d  # row aggregates from top of iteration (conservative)
        # 4a. WAIT -> EXEC
        is_w = (th.phase == WAIT) & ~th.forced
        key_w = cur_key
        hot_w = rows.hot[key_w]
        grantable = (is_w & (cur_tkt == d2.us[key_w])
                     & ~rows.updating[key_w]
                     & (rows.casc[key_w] == INF))
        # group locking: leader/follower bookkeeping
        if p.group_lock:
            open_leader = rows.gleader[key_w]
            is_leader_grant = grantable & hot_w & (open_leader == NOTK)
            is_member_grant = grantable & hot_w & (open_leader != NOTK)
        else:
            is_leader_grant = jnp.zeros_like(grantable)
            is_member_grant = jnp.zeros_like(grantable)

        qlen = d2.n_wait[key_w].astype(F32)
        if p.has_detection:
            dd = (p.dd_coeff * qlen).astype(I32)
        else:
            dd = jnp.zeros_like(cur_tkt)
        hotq = hot_w if p.hot_queue else jnp.zeros_like(hot_w)
        overhead = jnp.where(
            hotq,
            jnp.where(is_leader_grant | ~jnp.asarray(p.group_lock),
                      I32(p.lock_base), I32(p.grant_cost)),
            I32(p.lock_base) + dd)
        work_g = overhead + I32(c.op_exec)

        th = th._replace(
            phase=jnp.where(grantable, EXEC, th.phase),
            work=jnp.where(grantable, work_g, th.work))
        g = g._replace(
            wait_ticks=g.wait_ticks
            + jnp.sum(jnp.where(grantable, (now - th.wstart), 0)).astype(F32),
            lock_ops=g.lock_ops
            + jnp.sum(jnp.where(grantable & (~hotq | is_leader_grant), 1, 0)))

        upd_new = _seg_max(jnp.ones_like(key_w), key_w, R,
                           grantable) > 0
        rows = rows._replace(updating=rows.updating | upd_new)
        if p.group_lock:
            gl = rows.gleader
            gl = gl.at[key_w].max(jnp.where(is_leader_grant, cur_tkt, NOTK),
                                  mode="drop")
            gc = rows.gcount.at[key_w].add(
                jnp.where(is_leader_grant | is_member_grant, 1, 0),
                mode="drop")
            # close full groups; dynamic close when queue drained
            closed_full = gc >= p.batch_size
            closed_dyn = (jnp.asarray(p.dynamic_batch)
                          & (d2.n_wait == 0) & ~upd_new)
            close = (gl != NOTK) & (closed_full | closed_dyn)
            rows = rows._replace(
                gleader=jnp.where(close, NOTK, gl),
                gcount=jnp.where(close, 0, gc))

        # 4b. CWAIT -> COMMIT (commit order on early rows; leader hold)
        is_cw = (th.phase == CWAIT) & ~th.forced
        live = th.ticket >= 0
        cc_at = d2.cc[th.keys]
        order_ok = jnp.where(live & th.applied & th.early,
                             cc_at == th.ticket, True).all(axis=1)
        no_casc = jnp.where(live, rows.casc[th.keys] == INF, True).all(axis=1)
        if p.group_lock:
            lead_open = jnp.where(
                live & th.applied & th.early,
                rows.gleader[th.keys] == th.ticket, False).any(axis=1)
        else:
            lead_open = jnp.zeros((T,), bool)
        can_commit = is_cw & order_ok & no_casc & ~lead_open
        # injected aborts divert to rollback at the commit point
        vol = can_commit & th.willab
        can_commit = can_commit & ~th.willab

        base_cost = I32(c.commit_base + c.sync_lat)
        if p.group_commit and c.sync_lat > 0:
            # Group commit (Fig. 5c): while a hot row's sync window is in
            # flight, arriving commits of that row join it (binlog group
            # commit semantics); a new window starts only when the device
            # is free, so windows serialize. Amortization factor is thus
            # arrival-limited (~sync_lat / update-chain spacing).
            hrow = d2.hotof
            h_ok = hrow >= 0
            hrow_i = jnp.where(h_ok, hrow, 0)
            be = rows.batch_end[hrow_i]
            join = can_commit & h_ok & (be > now)
            fresh = can_commit & h_ok & ~join
            cost = jnp.where(join, (be - now) + I32(c.commit_base),
                             base_cost)
            nbe = rows.batch_end.at[hrow_i].max(
                jnp.where(fresh, now + I32(c.sync_lat), 0), mode="drop")
            rows = rows._replace(
                batch_end=nbe,
                batch_n=rows.batch_n.at[hrow_i].add(
                    jnp.where(can_commit & h_ok, 1, 0), mode="drop"))
        else:
            cost = jnp.broadcast_to(base_cost, (T,))
        th = th._replace(
            phase=jnp.where(can_commit, COMMIT,
                            jnp.where(vol, RBWAIT, th.phase)),
            work=jnp.where(can_commit, cost, th.work),
            wstart=jnp.where(vol, now, th.wstart),
            committing=th.committing | (can_commit[:, None] & th.applied),
            forced=th.forced | vol,
            vabort=th.vabort | vol)

        # ------------------------------------------------ 4c. RBWAIT->RBACK
        # (after 4b so voluntary commit-point aborts start their rollback
        # in the same iteration — otherwise dt can jump to a timeout.)
        # my turn iff for my early applied rows the top applied ticket is
        # mine (reverse update order, Alg. 3). No early applied rows => go.
        ea = th.applied & th.early & (th.ticket >= 0)
        top_at = d.top[th.keys]
        my_turn = jnp.where(ea, top_at == th.ticket, True).all(axis=1)
        # multi-row cascade cycles (paper §6.5's excluded case) break via
        # an out-of-order rollback after rb_turn_timeout
        my_turn = my_turn | ((now - th.wstart) >= c.rb_turn_timeout)
        start_rb = (th.phase == RBWAIT) & my_turn
        rb_work = c.rb_base + c.rb_per_op * d.napp
        th = th._replace(
            phase=jnp.where(start_rb, RBACK, th.phase),
            work=jnp.where(start_rb, rb_work, th.work))

        # ------------------------------------------------ 5. dt & advance
        paying = ((th.phase == EXEC) | (th.phase == COMMIT)
                  | (th.phase == RBACK) | (th.phase == BACKOFF)
                  | (th.phase == ARRIVE))
        starting = th.phase == START
        dt_pay = jnp.where(paying, th.work, INF).min()
        if p.wait_timeout > 0:
            texp = jnp.where(in_wait | (th.phase == CWAIT),
                             th.wstart + p.wait_timeout - now, INF).min()
        else:
            texp = INF
        rb_exp = jnp.where(th.phase == RBWAIT,
                           th.wstart + c.rb_turn_timeout - now, INF).min()
        texp = jnp.minimum(texp, jnp.maximum(rb_exp, 1))
        dt = jnp.minimum(dt_pay, jnp.maximum(texp, 1))
        dt = jnp.where(starting.any(), 0, dt)       # starts are instant
        dt = jnp.clip(dt, 0, jnp.maximum(stop_time - now, 1))
        now = now + dt
        work = jnp.where(paying, th.work - dt, th.work)
        th = th._replace(work=work)

        n_busy = ((th.phase == EXEC) | (th.phase == COMMIT)
                  | (th.phase == RBACK)).sum().astype(F32)
        g = g._replace(now=now, iters=g.iters + 1,
                       busy_ticks=g.busy_ticks + n_busy * dt.astype(F32))

        done = paying & (work <= 0)

        # ------------------------------------------------ 6. completions
        # 6a. EXEC done: apply the write, advance op
        e_done = done & (th.phase == EXEC)
        wr_now = cur(th.iswr, th.op) & e_done
        eff_wr = wr_now & ~cur(th.dup, th.op)
        rows = rows._replace(
            applied_val=rows.applied_val.at[cur_key].add(
                jnp.where(eff_wr, 1, 0), mode="drop"),
            updating=rows.updating & ~(
                _seg_max(jnp.ones_like(cur_key), cur_key, R, eff_wr) > 0))
        opc = jnp.clip(th.op, 0, L - 1)
        applied = th.applied.at[tids, opc].set(
            jnp.where(eff_wr, True, cur(th.applied, th.op)))
        # freeze the release semantics that were in force when we applied
        if p.early_all:
            early_now = jnp.ones_like(eff_wr)
        elif p.early_release:
            early_now = rows.hot[cur_key]
        else:
            early_now = jnp.zeros_like(eff_wr)
        early = th.early.at[tids, opc].set(
            jnp.where(eff_wr, early_now, cur(th.early, th.op)))
        th = th._replace(applied=applied, early=early)
        nop = th.op + jnp.where(e_done, 1, 0)
        txn_done = e_done & (nop >= th.nops)
        th = th._replace(op=nop)
        # forced threads stop making progress after their op completes
        to_park = e_done & th.forced
        th = th._replace(phase=jnp.where(to_park, RBWAIT, th.phase))
        e_done = e_done & ~to_park
        txn_done = txn_done & ~to_park
        th = th._replace(
            phase=jnp.where(txn_done, CWAIT, th.phase),
            wstart=jnp.where(txn_done, now, th.wstart))
        next_op = e_done & ~txn_done

        # 6b. COMMIT done: release everything, count, next txn
        c_done = done & (th.phase == COMMIT)
        rel = th.ticket >= 0
        committed_w = rel & th.applied & c_done[:, None]
        rows = rows._replace(
            committed_val=rows.committed_val.at[th.keys].add(
                jnp.where(committed_w, 1, 0), mode="drop"))
        lat = now - th.tstart
        g = g._replace(
            commits=g.commits + c_done.sum(),
            lat_sum=g.lat_sum + jnp.where(c_done, lat, 0).sum().astype(F32),
            hist=g.hist.at[_hist_bucket(lat)].add(
                jnp.where(c_done, 1, 0), mode="drop"))

        # 6c. RBACK done: revert applied writes, release tickets
        r_done = done & (th.phase == RBACK)
        reverted = rel & th.applied & r_done[:, None]
        rows = rows._replace(
            applied_val=rows.applied_val.at[th.keys].add(
                jnp.where(reverted, -1, 0), mode="drop"))
        g = g._replace(
            user_aborts=g.user_aborts + (r_done & th.vabort).sum(),
            forced_aborts=g.forced_aborts + (r_done & ~th.vabort).sum())

        clear = (c_done | r_done)[:, None]
        th = th._replace(
            ticket=jnp.where(clear, NOTK, th.ticket),
            applied=jnp.where(clear, False, th.applied),
            early=jnp.where(clear, False, th.early),
            committing=jnp.where(clear, False, th.committing))

        # 6d. BACKOFF done -> START; COMMIT/RBACK -> next
        # backoff is jittered per (thread, txn) to break retry lockstep
        # (identical-key retries re-forming the same deadlock forever)
        b_done = done & (th.phase == BACKOFF)
        jitter = ((tids * I32(40503) + th.txn * I32(9973)) % I32(4) + 1)
        th = th._replace(
            phase=jnp.where(c_done | b_done, START,
                            jnp.where(r_done, BACKOFF, th.phase)),
            work=jnp.where(r_done, c.backoff * jitter, th.work),
            txn=th.txn + jnp.where(c_done | (r_done & th.vabort), 1, 0),
            retry=jnp.where(r_done & ~th.vabort, True,
                            jnp.where(c_done, False, th.retry)),
            forced=jnp.where(r_done, False, th.forced),
            vabort=jnp.where(r_done, False, th.vabort),
            op=jnp.where(c_done | r_done, 0, nop))

        # 6e. ARRIVE done -> START
        a_done = done & (th.phase == ARRIVE)
        th = th._replace(phase=jnp.where(a_done, START, th.phase))

        # ------------------------------------------------ 7. START new txns
        st = th.phase == START
        past = now >= cfg.horizon
        th = th._replace(phase=jnp.where(st & past, HALT, th.phase))
        st = st & ~past
        if c.arrival_rate > 0:
            interval = max(int(T / c.arrival_rate), 1)
            arr = th.txn * interval + (tids * 977) % interval
            early_t = st & (arr > now)
            th = th._replace(
                phase=jnp.where(early_t, ARRIVE, th.phase),
                work=jnp.where(early_t, arr - now, th.work))
            st = st & ~early_t
        keys, iswr, dup, nops = gen_txn(w, tids, th.txn)
        wab = will_abort(w, cfg.p_abort, tids, th.txn)
        sel = st[:, None]
        th = th._replace(
            keys=jnp.where(sel, keys, th.keys),
            iswr=jnp.where(sel, iswr, th.iswr),
            dup=jnp.where(sel, dup, th.dup),
            nops=jnp.where(st, nops, th.nops),
            willab=jnp.where(st, wab, th.willab),
            tstart=jnp.where(st & ~th.retry, now, th.tstart),
            op=jnp.where(st, 0, th.op))

        # ------------------------------------------------ 8. begin next op
        # Threads entering a new op (fresh txns or op-advance) either take a
        # ticket (effective write) or execute directly (read / dup write).
        begin = st | next_op
        bkey = cur(th.keys, th.op)
        bwr = cur(th.iswr, th.op) & ~cur(th.dup, th.op)
        need_ticket = begin & bwr
        direct = begin & ~bwr
        rd_cost = jnp.where(cur(th.iswr, th.op), c.op_exec, c.read_exec)
        th = th._replace(
            phase=jnp.where(direct, EXEC, th.phase),
            work=jnp.where(direct, rd_cost, th.work))

        # FIFO ticket assignment with same-tick ranking (sort by key).
        # Sentinel key R sorts all non-takers after every real key so they
        # can never interleave (and break the rank chain) of a key run.
        enc = jnp.where(need_ticket, bkey, I32(R)) * I32(T) + tids
        order = jnp.argsort(enc)
        sk = bkey[order]
        sm = need_ticket[order]
        same = jnp.concatenate([jnp.zeros((1,), bool),
                                (sk[1:] == sk[:-1]) & sm[1:] & sm[:-1]])
        idx = jnp.arange(T)
        seg_start = jnp.where(~same, idx, 0)
        seg_start = lax.associative_scan(jnp.maximum, seg_start)
        rank_sorted = idx - seg_start
        rank = jnp.zeros((T,), I32).at[order].set(rank_sorted.astype(I32))
        tkt = jnp.where(need_ticket, rows.nt[bkey] + rank, NOTK)
        counts = _seg_sum(jnp.ones_like(bkey), bkey, R, need_ticket)
        rows = rows._replace(nt=rows.nt + counts)
        th = th._replace(
            ticket=th.ticket.at[tids, jnp.clip(th.op, 0, L - 1)].set(
                jnp.where(need_ticket, tkt, cur(th.ticket, th.op))),
            phase=jnp.where(need_ticket, WAIT, th.phase),
            wstart=jnp.where(need_ticket, now, th.wstart))

        # ------------------------------------------------ 9. hotspot detect
        if p.hot_queue:
            live3 = th.ticket >= 0
            d3_nwait = _seg_sum(jnp.ones_like(th.ticket), th.keys, R,
                                live3 & ~th.applied)
            d3_nlive = _seg_sum(jnp.ones_like(th.ticket), th.keys, R, live3)
            promote = d3_nwait > p.hot_threshold
            # demote only when the row is fully quiesced: no waiter AND no
            # applied-uncommitted update (the dep list must be empty, §4.1)
            demote = rows.hot & (d3_nlive == 0)
            rows = rows._replace(
                hot=(rows.hot | promote) & ~demote,
                gleader=jnp.where(demote, NOTK, rows.gleader),
                gcount=jnp.where(demote, 0, rows.gcount))

        return SimState(th, rows, g)

    return step


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def init_state(cfg: EngineConfig) -> SimState:
    T, L, R = cfg.n_threads, cfg.workload.txn_len, cfg.workload.n_rows
    th = Threads(
        phase=jnp.zeros((T,), I32),
        work=jnp.zeros((T,), I32),
        op=jnp.zeros((T,), I32),
        txn=jnp.zeros((T,), I32),
        tstart=jnp.zeros((T,), I32),
        wstart=jnp.zeros((T,), I32),
        willab=jnp.zeros((T,), bool),
        forced=jnp.zeros((T,), bool),
        vabort=jnp.zeros((T,), bool),
        retry=jnp.zeros((T,), bool),
        keys=jnp.zeros((T, L), I32),
        iswr=jnp.zeros((T, L), bool),
        dup=jnp.zeros((T, L), bool),
        ticket=jnp.full((T, L), NOTK),
        applied=jnp.zeros((T, L), bool),
        early=jnp.zeros((T, L), bool),
        committing=jnp.zeros((T, L), bool),
        nops=jnp.full((T,), L, I32),
    )
    rows = Rows(
        nt=jnp.zeros((R,), I32),
        updating=jnp.zeros((R,), bool),
        hot=jnp.zeros((R,), bool),
        gleader=jnp.full((R,), NOTK),
        gcount=jnp.zeros((R,), I32),
        casc=jnp.full((R,), INF),
        batch_end=jnp.zeros((R,), I32),
        batch_n=jnp.zeros((R,), I32),
        applied_val=jnp.zeros((R,), I32),
        committed_val=jnp.zeros((R,), I32),
    )
    g = Globals(
        now=jnp.asarray(0, I32),
        commits=jnp.asarray(0, I32),
        user_aborts=jnp.asarray(0, I32),
        forced_aborts=jnp.asarray(0, I32),
        lock_ops=jnp.asarray(0, I32),
        wait_ticks=jnp.asarray(0.0, F32),
        busy_ticks=jnp.asarray(0.0, F32),
        lat_sum=jnp.asarray(0.0, F32),
        hist=jnp.zeros((N_HIST,), I32),
        iters=jnp.asarray(0, I32),
    )
    return SimState(th, rows, g)


@functools.partial(jax.jit, static_argnums=0)
def _run(cfg: EngineConfig, s0: SimState) -> SimState:
    step = _make_step(cfg)
    stop_time = (cfg.horizon
                 + 3 * max(cfg.protocol.wait_timeout, cfg.horizon)
                 if cfg.drain else cfg.horizon)

    def cond(s: SimState):
        running = ((s.th.phase != HALT).any() & (s.g.now < stop_time)
                   if cfg.drain else (s.g.now < cfg.horizon))
        return running & (s.g.iters < cfg.max_iters)

    return lax.while_loop(cond, step, s0)


def run_sim(cfg: EngineConfig) -> SimState:
    """Run a simulation to completion and return the final state."""
    return _run(cfg, init_state(cfg))


def simulate(protocol: str, workload: WorkloadSpec, n_threads: int,
             costs: CostModel | None = None, horizon: int = 2_000_000,
             p_abort: float = 0.0, drain: bool = False, seed: int = 0,
             **proto_over) -> SimState:
    """Convenience entry point: run one protocol over one workload."""
    cfg = EngineConfig(
        protocol=protocol_params(protocol, **proto_over),
        costs=costs or CostModel(),
        workload=workload,
        n_threads=n_threads,
        horizon=horizon,
        p_abort=p_abort,
        drain=drain,
        seed=seed,
    )
    return run_sim(cfg)
