"""Vectorized discrete-time concurrency-control engine (the paper's core).

The engine simulates T database worker threads executing transactions over R
rows under one of six locking protocols (MySQL-2PL, O1 lightweight, O2
queue locking, TXSQL group locking, Bamboo, Brook-2PL), tick-accurately,
entirely as a compiled JAX program (``lax.while_loop`` over simulated time;
all state in arrays). Aria lives in ``aria.py`` (its batch structure needs
no tick loop). Brook-2PL ("Tolerating High Contention Workloads with A
Deadlock-Free Two-Phase Locking Protocol", Habibi et al., arXiv:2508.18576)
is the statically-analysed member: ``chop.py`` derives a canonical
lock-acquisition order and per-op release points from the workload's
transaction templates, and the engine consumes them through two masked
protocol branches — ``ordered_acquire`` (tickets taken in canonical row
order, making waits-for cycles structurally impossible: no detection walk,
no timeouts, no deadlock rollbacks) and ``per_op_release`` (a ticket
retires from the commit-order dependency at its key's last-use op, with
the ``cc``/``top`` cascade machinery still guarding dirty reads).

Modeling choices (see DESIGN.md §2.1):

* Every row's lock wait queue is a **ticket queue**: ``nt[r]`` is the next
  ticket; a thread takes a ticket when it reaches a write op. Queue/grant
  order is ticket order (FIFO, as in lock_sys / hot_row_hash).
* The grant rule is the protocol: strict-2PL rows grant ticket k when every
  ticket < k has *committed* (released); early-release rows (group-locking
  hot rows; every row under Bamboo) grant when every ticket < k has
  *applied its update* (Fig. 3).
* Rather than maintaining mutable queues, per-row aggregates (``us`` = next
  grantable ticket, ``cc`` = lowest uncommitted applied ticket = commit
  cursor, ``top`` = highest applied ticket, holder, queue length) are
  **re-derived every iteration from the per-thread ticket table** with
  segment reductions. Aborts simply clear ticket slots; order invariants
  are restored declaratively, which makes cascades and timeouts robust.
* The dependency list of the paper is exactly the ticket order of applied
  updates: commit requires ``cc[row] == my_ticket`` (commit order = update
  order, Alg. 2); cascades roll back from ``top`` downward (Alg. 3).
* Costs are integer ticks (0.1us); see ``costs.py`` for where each cost
  lands and why (deadlock-detection on the grant path reproduces Fig. 2a).

The per-row value is modeled as a counter: every applied write is +1 and
every rollback is -1, so serializability is *checkable*: at quiescence the
counter must equal the number of committed writes (no lost updates, no
dirty leftovers) — see tests/test_lock_properties.py.

Batching (DESIGN.md §3): every protocol flag, cost constant, and workload
parameter is a **traced jnp scalar** carried in :class:`DynParams`; the only
static compile keys are the array shapes (T, L, R) and the workload kind
(:class:`StaticShape`). Protocol branches are computed unconditionally and
selected with masks, so one compiled program serves *every* protocol /
timeout / abort-rate / skew combination at a given shape — and the sweep
subsystem (``repro.sweep``) can stack G configs and run them under
``jax.vmap`` as one program. ``simulate()`` routes through the very same
dynamic step, which makes vmapped-lane results bit-identical to per-config
runs by construction. Threads and op slots are padded to the grid max:
padded threads start in HALT and never act; padded slots never execute
(``nops`` stops the op cursor first), so padding is bitwise invisible.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .costs import CostModel, ProtocolParams, protocol_params
from .workload import (WorkloadSpec, DynWorkload, dyn_workload, gen_txn_dyn,
                       will_abort_dyn)

I32 = jnp.int32
F32 = jnp.float32
INF = jnp.int32(2**30)
NOTK = jnp.int32(-1)          # "no ticket"
N_HIST = 64
HIST_BASE = 1.3

# thread phases
START, WAIT, EXEC, CWAIT, COMMIT, RBACK, RBWAIT, BACKOFF, ARRIVE, HALT = \
    range(10)

# --- tick attribution (obs layer, DESIGN.md §11) -------------------------
# Every thread-tick of the horizon lands in exactly one TickBreakdown bin,
# split by protocol branch (cold = plain 2PL path, hot = the thread's
# current row is promoted hot), so sum(Globals.tb) == T * Globals.now is a
# hard conservation invariant (asserted in tests; i32, exact mod 2^32).
N_TB = 7
TB_EXEC, TB_LOCKWAIT, TB_COMMITWAIT, TB_ROLLBACK, TB_DETECT, TB_SYNC, \
    TB_IDLE = range(N_TB)
TB_NAMES = ("exec", "lock_wait", "commit_wait", "rollback", "detection",
            "sync", "idle")
TB_BRANCHES = ("cold", "hot")
# phase -> bin. START/ARRIVE/HALT are idle (no txn holds the thread);
# RBACK work + RBWAIT turn-waits + BACKOFF all charge the rollback bin;
# COMMIT work (commit_base + sync window) charges sync. EXEC splits at
# runtime: the deadlock-detection ticks folded into the grant overhead
# (Threads.detleft) are consumed first and charged to TB_DETECT.
_TB_PHASE_BIN = np.array(
    [TB_IDLE, TB_LOCKWAIT, TB_EXEC, TB_COMMITWAIT, TB_SYNC,
     TB_ROLLBACK, TB_ROLLBACK, TB_ROLLBACK, TB_IDLE, TB_IDLE],
    dtype=np.int32)

# log2 buckets for snapshot occupancy histograms: bucket 0 = empty,
# bucket b>=1 = count in [2**(b-1), 2**b). 12 buckets cover queues of 2k+.
N_QHIST = 12

# --- per-record contention attribution (obs layer, DESIGN.md §14) --------
# ``Globals.ca`` is an (N_CA, R) i32 accumulator scattered per ROW at the
# tick-charge site, the per-record twin of the per-phase TickBreakdown.
# CA_WAIT charges dt at the thread's current-op row under exactly the
# TB_LOCKWAIT mask, so ``ca[CA_WAIT].sum() == tb[:, TB_LOCKWAIT].sum()``
# (cold+hot) is a hard conservation invariant, asserted per run and per
# governed segment. Gated by the traced ``DynParams.attrib`` flag: the
# accumulator is write-only, so attribution-off runs are bit-exact with
# the pre-accumulator engine in every other leaf, with zero extra
# compiles. i32 like tb: exact mod 2^32.
N_CA = 6
CA_WAIT, CA_GRANTS, CA_TIMEOUTS, CA_VICTIMS, CA_QSUM, CA_QMAX = range(N_CA)
CA_NAMES = ("wait_ticks", "grants", "timeouts", "victims",
            "queue_sum", "queue_max")

# --- stage ablation (profiler seam, DESIGN.md §12) -----------------------
# ``_make_step_events(..., ablate={stage})`` replaces one named stage's
# compute with a shape-correct stand-in so XLA dead-code-eliminates the
# stage from the compiled program; the per-stage step profiler
# (``repro.obs.prof``) attributes per-iteration wall cost by differencing
# against the full step. Each ablation is the exact identity on the step
# whenever the stage's work is trivially absent (protocol flag off,
# read-only workload, txn_len 1 — asserted bit-exactly in
# tests/test_prof.py), and ``ablate=frozenset()`` (every production entry
# point) emits the identical program as before the seam existed.
PROF_STAGES = (
    "dup_analysis",    # gen_txn_dyn's (T,L,L) pairwise dup/last-use scan
    "deadlock_walk",   # the 8-hop waits-for cycle walk (stage 1b)
    "ticket_grant",    # grant-rule masks (4a) + FIFO ticket argsort (8)
    "commit_cursor",   # _derive: cc/top/us/holder T*L -> R seg reductions
    "group_hotspot",   # group-lock / group-commit / hotspot-detect conds
    "tick_charge",     # TickBreakdown scatter charging (stage 5)
)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    protocol: ProtocolParams
    costs: CostModel
    workload: WorkloadSpec
    n_threads: int = 64
    horizon: int = 2_000_000          # ticks (0.1us) => 0.2s simulated
    p_abort: float = 0.0              # injected commit-time aborts (Fig 10)
    drain: bool = False               # run until all threads quiesce
    max_iters: int = 1_500_000
    seed: int = 0
    attrib: bool = False              # per-record contention accumulator


class StaticShape(NamedTuple):
    """The compile key: everything that picks the program, nothing else."""
    kind: str           # workload kind
    n_threads: int      # padded thread count T
    txn_len: int        # padded op-slot count L
    n_rows: int         # key space R


class DynParams(NamedTuple):
    """Traced per-config parameters (one vmap lane each in a sweep).

    Protocol flags are jnp bools, costs jnp ints/floats; semantics match
    ``ProtocolParams`` / ``CostModel`` / ``EngineConfig`` field-for-field.
    ``n_active`` masks padded threads (tid >= n_active start in HALT).
    """
    # --- protocol ---
    lock_base: jnp.ndarray
    grant_cost: jnp.ndarray
    dd_coeff: jnp.ndarray
    has_detection: jnp.ndarray
    hot_queue: jnp.ndarray
    early_release: jnp.ndarray
    early_all: jnp.ndarray
    group_lock: jnp.ndarray
    group_commit: jnp.ndarray
    dynamic_batch: jnp.ndarray
    batch_size: jnp.ndarray
    hot_threshold: jnp.ndarray
    proactive_abort: jnp.ndarray
    ordered_acquire: jnp.ndarray
    per_op_release: jnp.ndarray
    wait_timeout: jnp.ndarray
    commit_wait_timeout: jnp.ndarray
    # --- costs ---
    op_exec: jnp.ndarray
    read_exec: jnp.ndarray
    commit_base: jnp.ndarray
    sync_lat: jnp.ndarray
    rb_base: jnp.ndarray
    rb_per_op: jnp.ndarray
    backoff: jnp.ndarray
    arrival_rate: jnp.ndarray
    rb_turn_timeout: jnp.ndarray
    # --- run ---
    horizon: jnp.ndarray
    p_abort: jnp.ndarray
    drain: jnp.ndarray
    max_iters: jnp.ndarray
    n_active: jnp.ndarray
    # (T,) per-thread transaction quota: a thread reaching START with
    # ``txn >= txn_cap[tid]`` HALTs instead of generating a new txn. INF
    # (the split_config default) is the closed loop — the check is then
    # identically false, so classic runs are bitwise unchanged. The
    # serving layer (repro.serving) meters this as admission credits and
    # revives HALTed slots between segments, which turns thread slots
    # into an open-system worker pool.
    txn_cap: jnp.ndarray
    # Per-record contention attribution on/off (Globals.ca). Traced like
    # every other knob — flipping it reuses the compiled program; the
    # accumulator is write-only so the off branch leaves every other
    # state leaf bit-exact.
    attrib: jnp.ndarray
    # --- workload ---
    wl: DynWorkload


def split_config(cfg: EngineConfig, pad_threads: int | None = None,
                 pad_len: int | None = None) -> tuple[StaticShape, DynParams]:
    """EngineConfig -> (compile key, traced params). Eager — not for jit."""
    p, c, w = cfg.protocol, cfg.costs, cfg.workload
    T = pad_threads or cfg.n_threads
    L = pad_len or w.txn_len
    assert T >= cfg.n_threads and L >= w.txn_len
    stat = StaticShape(kind=w.kind, n_threads=T, txn_len=L, n_rows=w.n_rows)
    i32 = lambda v: jnp.asarray(v, I32)
    f32 = lambda v: jnp.asarray(v, F32)
    b = lambda v: jnp.asarray(v, bool)
    dp = DynParams(
        lock_base=i32(p.lock_base), grant_cost=i32(p.grant_cost),
        dd_coeff=f32(p.dd_coeff), has_detection=b(p.has_detection),
        hot_queue=b(p.hot_queue), early_release=b(p.early_release),
        early_all=b(p.early_all), group_lock=b(p.group_lock),
        group_commit=b(p.group_commit), dynamic_batch=b(p.dynamic_batch),
        batch_size=i32(p.batch_size), hot_threshold=i32(p.hot_threshold),
        proactive_abort=b(p.proactive_abort),
        ordered_acquire=b(p.ordered_acquire),
        per_op_release=b(p.per_op_release),
        wait_timeout=i32(p.wait_timeout),
        commit_wait_timeout=i32(p.commit_wait_timeout),
        op_exec=i32(c.op_exec), read_exec=i32(c.read_exec),
        commit_base=i32(c.commit_base), sync_lat=i32(c.sync_lat),
        rb_base=i32(c.rb_base), rb_per_op=i32(c.rb_per_op),
        backoff=i32(c.backoff), arrival_rate=f32(c.arrival_rate),
        rb_turn_timeout=i32(c.rb_turn_timeout),
        horizon=i32(cfg.horizon), p_abort=f32(cfg.p_abort),
        drain=b(cfg.drain), max_iters=i32(cfg.max_iters),
        n_active=i32(cfg.n_threads),
        txn_cap=jnp.full((T,), INF, I32),
        attrib=b(cfg.attrib),
        wl=dyn_workload(w),
    )
    return stat, dp


class Threads(NamedTuple):
    phase: jnp.ndarray      # (T,)
    work: jnp.ndarray       # (T,) remaining ticks in paying phase
    op: jnp.ndarray         # (T,) current op slot
    txn: jnp.ndarray        # (T,) txn counter
    tstart: jnp.ndarray     # (T,) first-attempt start tick
    wstart: jnp.ndarray     # (T,) wait start tick
    willab: jnp.ndarray     # (T,) bool: injected abort at commit
    forced: jnp.ndarray     # (T,) bool: forced abort pending
    vabort: jnp.ndarray     # (T,) bool: abort is voluntary (move to next txn)
    retry: jnp.ndarray      # (T,) bool: current txn is a retry
    keys: jnp.ndarray       # (T, L)
    iswr: jnp.ndarray       # (T, L) bool
    dup: jnp.ndarray        # (T, L) bool
    ticket: jnp.ndarray     # (T, L) ticket or -1
    applied: jnp.ndarray    # (T, L) bool
    early: jnp.ndarray      # (T, L) bool: early-release semantics at apply
    committing: jnp.ndarray  # (T, L) bool: entered the commit queue
    lastu: jnp.ndarray      # (T, L) bool: slot is its key's last use (chop)
    released: jnp.ndarray   # (T, L) bool: ticket retired at its release pt
    nops: jnp.ndarray       # (T,)
    detleft: jnp.ndarray    # (T,) detection ticks left in current EXEC work


class Rows(NamedTuple):
    nt: jnp.ndarray         # (R,) next ticket
    updating: jnp.ndarray   # (R,) bool: an update is executing
    hot: jnp.ndarray        # (R,) bool
    gleader: jnp.ndarray    # (R,) leader ticket of OPEN group, -1 if closed
    gcount: jnp.ndarray     # (R,) members granted in open group
    casc: jnp.ndarray       # (R,) cascade low ticket (INF = none)
    batch_end: jnp.ndarray  # (R,) group-commit batch completion tick
    batch_n: jnp.ndarray    # (R,) members in the open commit batch
    applied_val: jnp.ndarray    # (R,) net applied increments
    committed_val: jnp.ndarray  # (R,) committed increments


class Globals(NamedTuple):
    now: jnp.ndarray
    commits: jnp.ndarray
    user_aborts: jnp.ndarray
    forced_aborts: jnp.ndarray
    lock_ops: jnp.ndarray
    wait_ticks: jnp.ndarray     # f32 (lock-wait thread-ticks)
    busy_ticks: jnp.ndarray     # f32 (executing/committing thread-ticks)
    lat_sum: jnp.ndarray        # f32
    hist: jnp.ndarray           # (N_HIST,) i32 latency histogram
    dd_ticks: jnp.ndarray       # deadlock-detection ticks paid on grants
    iters: jnp.ndarray
    tb: jnp.ndarray             # (len(TB_BRANCHES), N_TB) i32 TickBreakdown
    ca: jnp.ndarray             # (N_CA, R) i32 per-record contention


class SimState(NamedTuple):
    th: Threads
    rows: Rows
    g: Globals


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _seg_min(data, segs, R, valid):
    data = jnp.where(valid, data, INF)
    return jax.ops.segment_min(data.reshape(-1), segs.reshape(-1),
                               num_segments=R)


def _seg_max(data, segs, R, valid):
    data = jnp.where(valid, data, -1)
    return jax.ops.segment_max(data.reshape(-1), segs.reshape(-1),
                               num_segments=R)


def _seg_sum(data, segs, R, valid):
    data = jnp.where(valid, data, 0)
    return jax.ops.segment_sum(data.reshape(-1), segs.reshape(-1),
                               num_segments=R)


def _hist_bucket(lat):
    b = jnp.log(lat.astype(F32) + 1.0) / np.log(HIST_BASE)
    return jnp.clip(b.astype(I32), 0, N_HIST - 1)


def _stop_time(dp: DynParams):
    """Drain gets enough wall-clock past the horizon for timeouts to fire
    and cascades to unwind (livelocks then surface as drain failures)."""
    drain_stop = dp.horizon + 3 * jnp.maximum(dp.wait_timeout, dp.horizon)
    return jnp.where(dp.drain, drain_stop, dp.horizon)


class Derived(NamedTuple):
    us: jnp.ndarray           # (R,) next grantable ticket
    cc: jnp.ndarray           # (R,) commit cursor (lowest uncommitted applied)
    top: jnp.ndarray          # (R,) highest applied ticket (-1 none)
    holder: jnp.ndarray       # (R,) thread holding lowest live ticket (-1)
    n_wait: jnp.ndarray       # (R,) unapplied live tickets (queue length)
    n_live: jnp.ndarray       # (R,) all live tickets
    hotof: jnp.ndarray        # (T,) row of first early applied op (-1)
    napp: jnp.ndarray         # (T,) applied op count per thread


def _derive(stat: StaticShape, dp: DynParams, th: Threads,
            rows: Rows, ablate: frozenset = frozenset()) -> Derived:
    R = stat.n_rows
    T, L = th.keys.shape
    if "commit_cursor" in ablate:
        # profiler stand-in (DESIGN.md §12): every aggregate at its
        # no-live-ticket value — exact identity on read-only workloads,
        # DCEs the T*L -> R segment reductions otherwise.
        return Derived(
            us=rows.nt, cc=rows.nt, top=jnp.full((R,), NOTK),
            holder=jnp.full((R,), NOTK),
            n_wait=jnp.zeros((R,), I32), n_live=jnp.zeros((R,), I32),
            hotof=jnp.full((T,), NOTK), napp=jnp.zeros((T,), I32))
    live = th.ticket >= 0                                    # (T, L)
    keyf = th.keys

    # A slot's semantics are frozen when applied (th.early); a slot blocks
    # successors' updates unless it applied under early-release semantics.
    blocking = live & (~th.applied | ~th.early)
    us = _seg_min(th.ticket, keyf, R, blocking)
    us = jnp.where(us == INF, rows.nt, us)

    appl = live & th.applied
    # Commit cursor: with group commit, entering the commit queue releases
    # the *order* dependency (the batch syncs together, Fig. 5c); without
    # it, the dependency holds until the commit completes (slot cleared).
    # Brook per-op release retires a slot from the commit order at its
    # last-use op (th.released) — successors may commit ahead of the
    # releaser; the slot stays live/early so the cascade guard still sees
    # it if the releaser is nonetheless forced to abort.
    cc_block = appl & (~th.committing | ~dp.group_commit) & ~th.released
    cc = _seg_min(th.ticket, keyf, R, cc_block)
    cc = jnp.where(cc == INF, us, cc)
    top = _seg_max(th.ticket, keyf, R, appl & ~th.committing)

    tid = jnp.broadcast_to(jnp.arange(T, dtype=I32)[:, None], (T, L))
    enc = th.ticket * I32(T) + tid
    hmin = _seg_min(enc, keyf, R, live)
    holder = jnp.where(hmin == INF, NOTK, hmin % I32(T))

    n_wait = _seg_sum(jnp.ones_like(th.ticket), keyf, R, live & ~th.applied)
    n_live = _seg_sum(jnp.ones_like(th.ticket), keyf, R, live)

    ea = appl & th.early                                     # (T, L)
    first = jnp.argmax(ea, axis=1)
    hotof = jnp.where(ea.any(axis=1),
                      keyf[jnp.arange(T), first], NOTK)
    napp = appl.sum(axis=1).astype(I32)
    return Derived(us, cc, top, holder, n_wait, n_live, hotof, napp)


# ---------------------------------------------------------------------------
# engine step
# ---------------------------------------------------------------------------

class StepEvents(NamedTuple):
    """Per-iteration event masks surfaced by :func:`_make_step_events`.

    Everything here is *already computed* by the step — this tuple only
    names the masks so the obs layer (``repro.obs.trace``) can record
    them into a ring buffer inside the same ``lax.while_loop``. The
    classic entry points drop the tuple on the floor, and XLA dead-code
    eliminates it, so exposing events costs the untraced engine nothing.

    Mask timing: ``grant``/``group_join``/``timeout``/``victim`` describe
    transitions decided at the *start* of the interval (timestamp
    ``t_pre``); ``release``/``commit``/``wait_enter``/``abort`` fire at
    its end (``t_post``). Rows: ``row_cur`` is the thread's current-op
    row for start-of-interval events and ``release``; ``row_begin`` is
    the row of the op begun this iteration (``wait_enter``); ``commit``
    and ``abort`` are thread-level events (row -1 in the trace).

    ``abort`` fires when a rollback COMPLETES, whatever forced it —
    timeout, deadlock victim, injected commit-point abort (``p_abort``),
    cascade, or proactive rollback. ``timeout``/``victim`` only cover
    the first two causes, so without this mask a trace consumer cannot
    partition a thread's events into transaction attempts once aborts
    are injected — the serializability certifier
    (``repro.analysis.isolation``) needs the terminator itself. Its
    timestamp is also the instant the reverts landed and the tickets
    were released (step 6c runs in the same iteration).
    """
    t_pre: jnp.ndarray       # () tick at interval start
    t_post: jnp.ndarray      # () tick at interval end
    row_cur: jnp.ndarray     # (T,) current-op row at interval start
    row_begin: jnp.ndarray   # (T,) row of the op begun this iteration
    grant: jnp.ndarray       # (T,) bool WAIT -> EXEC lock grant
    group_join: jnp.ndarray  # (T,) bool grant joined an open hot group
    timeout: jnp.ndarray     # (T,) bool lock/commit wait timed out
    victim: jnp.ndarray      # (T,) bool chosen as deadlock victim
    release: jnp.ndarray     # (T,) bool brook per-op early release
    commit: jnp.ndarray      # (T,) bool txn committed
    wait_enter: jnp.ndarray  # (T,) bool took a ticket, entered WAIT
    abort: jnp.ndarray       # (T,) bool rollback completed (any cause)


def _make_step_events(stat: StaticShape, dp: DynParams, until=None,
                      ablate: frozenset = frozenset()):
    """Build the tick-step function. ``stat`` is static (shapes + kind);
    every parameter in ``dp`` is traced, so protocol branches are computed
    unconditionally and masked — the price of one program for all configs.

    ``ablate`` (static, profiler-only — see :data:`PROF_STAGES` and
    ``repro.obs.prof``) names stages whose compute is replaced by a
    shape-correct stand-in so XLA eliminates them from the program. The
    default empty set takes the exact code path that existed before the
    seam — production entry points never pass it.

    ``until`` (traced, segmented mode) caps the *idle* time advance at
    the segment boundary: when no thread is paying work (a pure wait
    window — e.g. a detection-free deadlock whose only pending event is
    a distant timeout) the jump stops at ``until`` instead of skipping
    past it, so a governor can resolve the stall by switching protocol.
    Busy steps are NEVER split: real events may overshoot the boundary
    by one completion, which keeps the step sequence of a segmented run
    literally identical to the single-shot run — several engine rules
    advance per loop iteration (the group-commit queue drains one
    member per derive), so injecting partial iterations into busy
    execution would change event timing. Extra iterations occur only
    inside all-waiting windows, where every stage is a state no-op
    (grantability, aborts, and hotspot transitions are pure functions
    of the frozen state and were already applied at the window's
    opening event; timeouts fire on ``now`` crossings that the idle
    jump never passes) — so only the diagnostic ``Globals.iters`` can
    differ, and only across stall windows split by boundaries.
    """
    T = stat.n_threads
    R = stat.n_rows
    L = stat.txn_len
    ablate = frozenset(ablate)
    assert ablate <= set(PROF_STAGES), sorted(ablate - set(PROF_STAGES))
    tids = jnp.arange(T, dtype=I32)
    tb_bin = jnp.asarray(_TB_PHASE_BIN)
    stop_time = _stop_time(dp)
    idle_stop = stop_time if until is None else jnp.minimum(stop_time,
                                                            until)

    def cur(field_tl, oph):
        """Gather per-thread value at its current op slot (clipped)."""
        return field_tl[tids, jnp.clip(oph, 0, L - 1)]

    def step(s: SimState) -> tuple[SimState, StepEvents]:
        th, rows, g = s
        with jax.named_scope("stage_derive"):
            d = _derive(stat, dp, th, rows, ablate)
        now = g.now

        cur_key = cur(th.keys, th.op)
        cur_tkt = cur(th.ticket, th.op)
        in_wait = th.phase == WAIT

        # ------------------------------------------------ 1. mark aborts
        forced = th.forced
        # 1a. wait timeout (wait_timeout <= 0 disables both timeouts)
        to = in_wait & ((now - th.wstart) >= dp.wait_timeout)
        to |= (th.phase == CWAIT) & (
            (now - th.wstart) >= dp.commit_wait_timeout)
        to_fire = to & (dp.wait_timeout > 0)
        forced = forced | to_fire
        # 1b. deadlock detection (waits-for cycle walk, up to 8 hops),
        # 2PL-style protocols. One victim per cycle: its max thread id.
        # lax.cond so single-config runs of detection-free protocols skip
        # the walk at runtime; vmapped lanes lower it to a select.
        def _walk_cycle(op):
            in_wait_, phase_, holder_at = op
            succ = jnp.where(in_wait_, holder_at, NOTK)
            succ = jnp.where(succ == tids, NOTK, succ)   # self-wait: none
            walk = succ
            mx = tids
            on_cycle = jnp.zeros_like(in_wait_)
            for _ in range(8):
                ok = walk >= 0
                wi = jnp.where(ok, walk, 0)
                mx = jnp.maximum(mx, jnp.where(ok, walk, -1))
                on_cycle = on_cycle | (ok & (walk == tids))
                # follow only through threads that are themselves waiting
                walk = jnp.where(ok & (phase_[wi] == WAIT),
                                 succ[wi], NOTK)
            return on_cycle & (tids == mx)

        if "deadlock_walk" in ablate:
            # stand-in: no victims (identity when has_detection is False)
            victim = jnp.zeros_like(in_wait)
        else:
            with jax.named_scope("stage_deadlock_walk"):
                victim = lax.cond(dp.has_detection, _walk_cycle,
                                  lambda op: jnp.zeros_like(op[0]),
                                  (in_wait, th.phase, d.holder[cur_key]))
        forced = forced | victim
        # 1c. proactive hot+non-hot rollback (§4.5)
        hrow = d.hotof
        hold = d.holder[cur_key]
        hold_ok = hold >= 0
        hold_i = jnp.where(hold_ok, hold, 0)
        pro = (in_wait & (hrow >= 0) & hold_ok
               & ~rows.hot[cur_key]
               & (d.hotof[hold_i] == hrow) & (hold != tids))
        forced = forced | (pro & dp.proactive_abort)
        # 1d. cascade propagation: any applied early ticket >= casc[key]
        casc_at = rows.casc[th.keys]                          # (T, L)
        hit = (th.applied & th.early & (th.ticket >= 0)
               & (th.ticket >= casc_at))
        forced = forced | hit.any(axis=1)
        # threads that cannot abort anymore (committing) stay
        forced = forced & (th.phase != COMMIT) & (th.phase != HALT)

        # forced threads with applied early tickets keep cascades open
        # (idempotent marking — covers voluntary commit-point aborts too,
        # which become forced outside this stage)
        casc_src = (th.applied & th.early & (th.ticket >= 0)
                    & forced[:, None])
        casc_min = _seg_min(th.ticket, th.keys, R, casc_src)
        casc = jnp.minimum(rows.casc, casc_min)
        # clear finished cascades: no applied ticket at/above casc remains
        casc = jnp.where((casc < INF) & (d.top < casc), INF, casc)
        rows = rows._replace(casc=casc)
        th = th._replace(forced=forced)

        # ------------------------------------------------ 2. divert to RBWAIT
        # forced threads in WAIT/CWAIT park for their cascade turn.
        parkable = forced & ((th.phase == WAIT) | (th.phase == CWAIT))
        phase = jnp.where(parkable, RBWAIT, th.phase)
        th = th._replace(phase=phase,
                         wstart=jnp.where(parkable, now, th.wstart))

        # ------------------------------------------------ 4. grants
        d2 = d  # row aggregates from top of iteration (conservative)
        # 4a. WAIT -> EXEC
        is_w = (th.phase == WAIT) & ~th.forced
        key_w = cur_key
        hot_w = rows.hot[key_w]
        with jax.named_scope("stage_ticket_grant"):
            grantable = (is_w & (cur_tkt == d2.us[key_w])
                         & ~rows.updating[key_w]
                         & (rows.casc[key_w] == INF))
        if "ticket_grant" in ablate:
            # stand-in: nothing grants (identity on read-only workloads,
            # where no thread ever takes a ticket or enters WAIT)
            grantable = jnp.zeros_like(grantable)
        # group locking: leader/follower bookkeeping
        open_leader = rows.gleader[key_w]
        is_leader_grant = (grantable & hot_w & (open_leader == NOTK)
                           & dp.group_lock)
        is_member_grant = (grantable & hot_w & (open_leader != NOTK)
                           & dp.group_lock)

        qlen = d2.n_wait[key_w].astype(F32)
        dd = jnp.where(dp.has_detection,
                       (dp.dd_coeff * qlen).astype(I32), 0)
        hotq = hot_w & dp.hot_queue
        overhead = jnp.where(
            hotq,
            jnp.where(is_leader_grant | ~dp.group_lock,
                      dp.lock_base, dp.grant_cost),
            dp.lock_base + dd)
        work_g = overhead + dp.op_exec

        th = th._replace(
            phase=jnp.where(grantable, EXEC, th.phase),
            work=jnp.where(grantable, work_g, th.work),
            # detection ticks inside this grant's work (tick attribution)
            detleft=jnp.where(grantable, jnp.where(hotq, 0, dd),
                              th.detleft))
        g = g._replace(
            wait_ticks=g.wait_ticks
            + jnp.sum(jnp.where(grantable, (now - th.wstart), 0)).astype(F32),
            lock_ops=g.lock_ops
            + jnp.sum(jnp.where(grantable & (~hotq | is_leader_grant), 1, 0)),
            dd_ticks=g.dd_ticks
            + jnp.sum(jnp.where(grantable & ~hotq, dd, 0)))

        upd_new = _seg_max(jnp.ones_like(key_w), key_w, R,
                           grantable) > 0
        rows = rows._replace(updating=rows.updating | upd_new)

        # group bookkeeping: without group locking gleader stays NOTK and
        # gcount 0, so the off branch is the identity (runtime-skipped for
        # single-config non-group runs, select under vmap).
        def _glock_on(op):
            gl, gc = op
            gl = gl.at[key_w].max(jnp.where(is_leader_grant, cur_tkt, NOTK),
                                  mode="drop")
            gc = gc.at[key_w].add(
                jnp.where(is_leader_grant | is_member_grant, 1, 0),
                mode="drop")
            # close full groups; dynamic close when queue drained
            closed_full = gc >= dp.batch_size
            closed_dyn = dp.dynamic_batch & (d2.n_wait == 0) & ~upd_new
            close = (gl != NOTK) & (closed_full | closed_dyn)
            return (jnp.where(close, NOTK, gl), jnp.where(close, 0, gc))

        if "group_hotspot" in ablate:
            gl, gc = rows.gleader, rows.gcount     # forced off branch
        else:
            with jax.named_scope("stage_group_lock"):
                gl, gc = lax.cond(dp.group_lock, _glock_on, lambda op: op,
                                  (rows.gleader, rows.gcount))
        rows = rows._replace(gleader=gl, gcount=gc)

        # 4b. CWAIT -> COMMIT (commit order on early rows; leader hold)
        is_cw = (th.phase == CWAIT) & ~th.forced
        live = th.ticket >= 0
        cc_at = d2.cc[th.keys]
        # released slots are OUT of the commit order entirely (brook):
        # the releaser itself must not wait for cc to reach a ticket that
        # cc now skips — only early-but-unreleased slots order commits.
        order_ok = jnp.where(live & th.applied & th.early & ~th.released,
                             cc_at == th.ticket, True).all(axis=1)
        no_casc = jnp.where(live, rows.casc[th.keys] == INF, True).all(axis=1)
        lead_open = (jnp.where(live & th.applied & th.early,
                               rows.gleader[th.keys] == th.ticket,
                               False).any(axis=1)
                     & dp.group_lock)
        can_commit = is_cw & order_ok & no_casc & ~lead_open
        # injected aborts divert to rollback at the commit point
        vol = can_commit & th.willab
        can_commit = can_commit & ~th.willab

        base_cost = dp.commit_base + dp.sync_lat

        # Group commit (Fig. 5c): while a hot row's sync window is in
        # flight, arriving commits of that row join it (binlog group
        # commit semantics); a new window starts only when the device
        # is free, so windows serialize. Amortization factor is thus
        # arrival-limited (~sync_lat / update-chain spacing). Off branch:
        # cost = base, no window bookkeeping.
        def _gcommit_on(op):
            batch_end, batch_n = op
            hrow = d2.hotof
            h_ok = hrow >= 0
            hrow_i = jnp.where(h_ok, hrow, 0)
            be = batch_end[hrow_i]
            join = can_commit & h_ok & (be > now)
            fresh = can_commit & h_ok & ~join
            cost = jnp.where(join, (be - now) + dp.commit_base,
                             jnp.broadcast_to(base_cost, (T,)))
            nbe = batch_end.at[hrow_i].max(
                jnp.where(fresh, now + dp.sync_lat, 0), mode="drop")
            nbn = batch_n.at[hrow_i].add(
                jnp.where(can_commit & h_ok, 1, 0), mode="drop")
            return nbe, nbn, cost

        def _gcommit_off(op):
            return op[0], op[1], jnp.broadcast_to(base_cost, (T,))

        if "group_hotspot" in ablate:
            nbe, nbn, cost = _gcommit_off((rows.batch_end, rows.batch_n))
        else:
            with jax.named_scope("stage_group_commit"):
                nbe, nbn, cost = lax.cond(dp.group_commit
                                          & (dp.sync_lat > 0),
                                          _gcommit_on, _gcommit_off,
                                          (rows.batch_end, rows.batch_n))
        rows = rows._replace(batch_end=nbe, batch_n=nbn)
        th = th._replace(
            phase=jnp.where(can_commit, COMMIT,
                            jnp.where(vol, RBWAIT, th.phase)),
            work=jnp.where(can_commit, cost, th.work),
            wstart=jnp.where(vol, now, th.wstart),
            committing=th.committing | (can_commit[:, None] & th.applied),
            forced=th.forced | vol,
            vabort=th.vabort | vol)

        # ------------------------------------------------ 4c. RBWAIT->RBACK
        # (after 4b so voluntary commit-point aborts start their rollback
        # in the same iteration — otherwise dt can jump to a timeout.)
        # my turn iff for my early applied rows the top applied ticket is
        # mine (reverse update order, Alg. 3). No early applied rows => go.
        ea = th.applied & th.early & (th.ticket >= 0)
        top_at = d.top[th.keys]
        my_turn = jnp.where(ea, top_at == th.ticket, True).all(axis=1)
        # multi-row cascade cycles (paper §6.5's excluded case) break via
        # an out-of-order rollback after rb_turn_timeout
        my_turn = my_turn | ((now - th.wstart) >= dp.rb_turn_timeout)
        start_rb = (th.phase == RBWAIT) & my_turn
        rb_work = dp.rb_base + dp.rb_per_op * d.napp
        th = th._replace(
            phase=jnp.where(start_rb, RBACK, th.phase),
            work=jnp.where(start_rb, rb_work, th.work))

        # ------------------------------------------------ 5. dt & advance
        paying = ((th.phase == EXEC) | (th.phase == COMMIT)
                  | (th.phase == RBACK) | (th.phase == BACKOFF)
                  | (th.phase == ARRIVE))
        starting = th.phase == START
        dt_pay = jnp.where(paying, th.work, INF).min()
        texp = jnp.where((in_wait | (th.phase == CWAIT))
                         & (dp.wait_timeout > 0),
                         th.wstart + dp.wait_timeout - now, INF).min()
        rb_exp = jnp.where(th.phase == RBWAIT,
                           th.wstart + dp.rb_turn_timeout - now, INF).min()
        texp = jnp.minimum(texp, jnp.maximum(rb_exp, 1))
        dt = jnp.minimum(dt_pay, jnp.maximum(texp, 1))
        dt = jnp.where(starting.any(), 0, dt)       # starts are instant
        # idle windows (nothing paying) stop at the segment boundary;
        # busy steps keep single-shot event timing (see docstring above)
        cap = jnp.where(dt_pay == INF, idle_stop, stop_time)
        dt = jnp.clip(dt, 0, jnp.maximum(cap - now, 1))
        now = now + dt
        work = jnp.where(paying, th.work - dt, th.work)
        th = th._replace(work=work)

        n_busy = ((th.phase == EXEC) | (th.phase == COMMIT)
                  | (th.phase == RBACK)).sum().astype(F32)
        g = g._replace(now=now, iters=g.iters + 1,
                       busy_ticks=g.busy_ticks + n_busy * dt.astype(F32))

        # --- tick attribution (obs, DESIGN.md §11): charge dt to exactly
        # one TickBreakdown bin per thread. Branch 1 ("hot") when the
        # thread is engaged on a promoted-hot row; EXEC pays its pending
        # detection ticks (detleft, set at grant) before exec proper.
        # Each iteration contributes exactly T*dt across bins, so
        # sum(g.tb) == T * g.now holds at every observation point.
        is_ex = th.phase == EXEC
        ddpay = jnp.where(is_ex, jnp.minimum(th.detleft, dt), 0)
        th = th._replace(detleft=th.detleft - ddpay)
        if "tick_charge" in ablate:
            pass    # stand-in: tb untouched — every other leaf (incl.
            #         detleft above) evolves bit-exactly on ANY config
        else:
            with jax.named_scope("stage_tick_charge"):
                engaged = ((th.phase == WAIT) | is_ex
                           | (th.phase == CWAIT) | (th.phase == COMMIT))
                branch = jnp.where(engaged & rows.hot[cur_key], 1, 0)
                tbf = g.tb.reshape(-1)
                tbf = tbf.at[branch * N_TB + tb_bin[th.phase]].add(
                    jnp.where(is_ex, dt - ddpay, dt))
                tbf = tbf.at[branch * N_TB + TB_DETECT].add(ddpay)
                g = g._replace(tb=tbf.reshape(g.tb.shape))

                # per-record contention attribution (DESIGN.md §14): the
                # masks this iteration already computed, scattered per
                # ROW instead of per phase bin. CA_WAIT uses exactly the
                # mask/time that charges TB_LOCKWAIT (phase still WAIT at
                # stage 5 pays dt at its current-op row), making
                # ca[CA_WAIT].sum() == tb[:, TB_LOCKWAIT].sum() exact.
                # Nothing downstream reads ca, so the off branch leaves
                # every other leaf bit-exact; lax.cond skips the
                # scatters at runtime for attrib-off single-config runs
                # (select under vmap).
                def _ca_on(ca):
                    ca = ca.at[CA_WAIT, cur_key].add(
                        jnp.where(th.phase == WAIT, dt, 0), mode="drop")
                    ca = ca.at[CA_GRANTS, cur_key].add(
                        jnp.where(grantable, 1, 0), mode="drop")
                    ca = ca.at[CA_TIMEOUTS, cur_key].add(
                        jnp.where(to_fire & in_wait, 1, 0), mode="drop")
                    ca = ca.at[CA_VICTIMS, cur_key].add(
                        jnp.where(victim, 1, 0), mode="drop")
                    ca = ca.at[CA_QSUM].add(d.n_wait * dt)
                    ca = ca.at[CA_QMAX].max(d.n_wait)
                    return ca

                g = g._replace(ca=lax.cond(dp.attrib, _ca_on,
                                           lambda ca: ca, g.ca))

        done = paying & (work <= 0)

        # ------------------------------------------------ 6. completions
        # 6a. EXEC done: apply the write, advance op
        e_done = done & (th.phase == EXEC)
        wr_now = cur(th.iswr, th.op) & e_done
        eff_wr = wr_now & ~cur(th.dup, th.op)
        rows = rows._replace(
            applied_val=rows.applied_val.at[cur_key].add(
                jnp.where(eff_wr, 1, 0), mode="drop"),
            updating=rows.updating & ~(
                _seg_max(jnp.ones_like(cur_key), cur_key, R, eff_wr) > 0))
        opc = jnp.clip(th.op, 0, L - 1)
        applied = th.applied.at[tids, opc].set(
            jnp.where(eff_wr, True, cur(th.applied, th.op)))
        # freeze the release semantics that were in force when we applied
        early_now = dp.early_all | (dp.early_release & rows.hot[cur_key])
        early = th.early.at[tids, opc].set(
            jnp.where(eff_wr, early_now, cur(th.early, th.op)))
        th = th._replace(applied=applied, early=early)
        # Brook-2PL per-op release (chop.py): when an op completes at its
        # key's LAST use, the key's ticket retires — `early` opens the
        # grant path and `released` drops the commit-order dependency, so
        # successors lock, update, AND commit ahead of the releaser.
        # Gated on ~willab: a txn that will abort at its commit point
        # keeps strict-2PL holds, so no dirty read can ever involve an
        # aborting brook txn — deadlock-free AND cascade-free. If a
        # released txn is nonetheless forced (brook_guard timeouts after
        # a governed switch-in), its early slots open a cascade on the
        # row, which freezes further grants AND commits there (no_casc)
        # until the dependents drain via their own timeouts; successor
        # writes are commutative increments, so the counter invariant
        # survives the out-of-order revert (same argument as
        # rb_turn_timeout in costs.py).
        rel_now = (e_done & cur(th.lastu, th.op) & dp.per_op_release
                   & ~th.forced & ~th.willab)
        rel_slot = ((th.keys == cur_key[:, None]) & (th.ticket >= 0)
                    & rel_now[:, None])
        th = th._replace(
            released=th.released | rel_slot,
            early=th.early | (rel_slot & th.applied))
        nop = th.op + jnp.where(e_done, 1, 0)
        txn_done = e_done & (nop >= th.nops)
        th = th._replace(op=nop)
        # forced threads stop making progress after their op completes
        to_park = e_done & th.forced
        th = th._replace(phase=jnp.where(to_park, RBWAIT, th.phase))
        e_done = e_done & ~to_park
        txn_done = txn_done & ~to_park
        th = th._replace(
            phase=jnp.where(txn_done, CWAIT, th.phase),
            wstart=jnp.where(txn_done, now, th.wstart))
        next_op = e_done & ~txn_done

        # 6b. COMMIT done: release everything, count, next txn
        c_done = done & (th.phase == COMMIT)
        rel = th.ticket >= 0
        committed_w = rel & th.applied & c_done[:, None]
        rows = rows._replace(
            committed_val=rows.committed_val.at[th.keys].add(
                jnp.where(committed_w, 1, 0), mode="drop"))
        lat = now - th.tstart
        g = g._replace(
            commits=g.commits + c_done.sum(),
            lat_sum=g.lat_sum + jnp.where(c_done, lat, 0).sum().astype(F32),
            hist=g.hist.at[_hist_bucket(lat)].add(
                jnp.where(c_done, 1, 0), mode="drop"))

        # 6c. RBACK done: revert applied writes, release tickets
        r_done = done & (th.phase == RBACK)
        reverted = rel & th.applied & r_done[:, None]
        rows = rows._replace(
            applied_val=rows.applied_val.at[th.keys].add(
                jnp.where(reverted, -1, 0), mode="drop"))
        g = g._replace(
            user_aborts=g.user_aborts + (r_done & th.vabort).sum(),
            forced_aborts=g.forced_aborts + (r_done & ~th.vabort).sum())

        clear = (c_done | r_done)[:, None]
        th = th._replace(
            ticket=jnp.where(clear, NOTK, th.ticket),
            applied=jnp.where(clear, False, th.applied),
            early=jnp.where(clear, False, th.early),
            committing=jnp.where(clear, False, th.committing),
            released=jnp.where(clear, False, th.released))

        # 6d. BACKOFF done -> START; COMMIT/RBACK -> next
        # backoff is jittered per (thread, txn) to break retry lockstep
        # (identical-key retries re-forming the same deadlock forever)
        b_done = done & (th.phase == BACKOFF)
        jitter = ((tids * I32(40503) + th.txn * I32(9973)) % I32(4) + 1)
        th = th._replace(
            phase=jnp.where(c_done | b_done, START,
                            jnp.where(r_done, BACKOFF, th.phase)),
            work=jnp.where(r_done, dp.backoff * jitter, th.work),
            txn=th.txn + jnp.where(c_done | (r_done & th.vabort), 1, 0),
            retry=jnp.where(r_done & ~th.vabort, True,
                            jnp.where(c_done, False, th.retry)),
            forced=jnp.where(r_done, False, th.forced),
            vabort=jnp.where(r_done, False, th.vabort),
            op=jnp.where(c_done | r_done, 0, nop))

        # 6e. ARRIVE done -> START
        a_done = done & (th.phase == ARRIVE)
        th = th._replace(phase=jnp.where(a_done, START, th.phase))

        # ------------------------------------------------ 7. START new txns
        # A thread halts at the horizon OR when its transaction quota is
        # exhausted (txn_cap; INF in closed loop). The quota check sits
        # exactly where the horizon check does, so a capped thread halts
        # the instant its last credited txn commits (6d set START this
        # same iteration) — the serving layer revives it with new credits
        # at the next segment boundary.
        st = th.phase == START
        past = (now >= dp.horizon) | (th.txn >= dp.txn_cap)
        th = th._replace(phase=jnp.where(st & past, HALT, th.phase))
        st = st & ~past
        # fixed-TPS open loop: arrival_rate <= 0 means closed loop (no gate).
        # n_active (not the padded T) sets the per-thread arrival interval.
        rate_on = dp.arrival_rate > 0
        interval = jnp.maximum(
            (dp.n_active.astype(F32)
             / jnp.where(rate_on, dp.arrival_rate, F32(1.0))).astype(I32),
            1)
        arr = th.txn * interval + (tids * 977) % interval
        early_t = st & (arr > now) & rate_on
        th = th._replace(
            phase=jnp.where(early_t, ARRIVE, th.phase),
            work=jnp.where(early_t, arr - now, th.work))
        st = st & ~early_t
        with jax.named_scope("stage_gen_txn"):
            keys, iswr, dup, lastu, nops = gen_txn_dyn(
                stat.kind, R, L, dp.wl, tids, th.txn,
                acq_order=dp.ordered_acquire,
                skip_analysis="dup_analysis" in ablate)
        wab = will_abort_dyn(dp.wl.seed, dp.p_abort, tids, th.txn)
        sel = st[:, None]
        th = th._replace(
            keys=jnp.where(sel, keys, th.keys),
            iswr=jnp.where(sel, iswr, th.iswr),
            dup=jnp.where(sel, dup, th.dup),
            lastu=jnp.where(sel, lastu, th.lastu),
            nops=jnp.where(st, nops, th.nops),
            willab=jnp.where(st, wab, th.willab),
            tstart=jnp.where(st & ~th.retry, now, th.tstart),
            op=jnp.where(st, 0, th.op))

        # ------------------------------------------------ 8. begin next op
        # Threads entering a new op (fresh txns or op-advance) either take a
        # ticket (effective write) or execute directly (read / dup write).
        begin = st | next_op
        bkey = cur(th.keys, th.op)
        bwr = cur(th.iswr, th.op) & ~cur(th.dup, th.op)
        need_ticket = begin & bwr
        direct = begin & ~bwr
        rd_cost = jnp.where(cur(th.iswr, th.op), dp.op_exec, dp.read_exec)
        th = th._replace(
            phase=jnp.where(direct, EXEC, th.phase),
            work=jnp.where(direct, rd_cost, th.work),
            # direct exec pays no grant overhead: no detection to attribute
            detleft=jnp.where(direct, 0, th.detleft))

        # FIFO ticket assignment with same-tick ranking (sort by key).
        # Sentinel key R sorts all non-takers after every real key so they
        # can never interleave (and break the rank chain) of a key run.
        if "ticket_grant" in ablate:
            # stand-in: no same-tick ranking (exact when need_ticket is
            # everywhere false — read-only workloads take no tickets)
            rank = jnp.zeros((T,), I32)
        else:
            with jax.named_scope("stage_ticket_assign"):
                enc = jnp.where(need_ticket, bkey, I32(R)) * I32(T) + tids
                order = jnp.argsort(enc)
                sk = bkey[order]
                sm = need_ticket[order]
                same = jnp.concatenate([
                    jnp.zeros((1,), bool),
                    (sk[1:] == sk[:-1]) & sm[1:] & sm[:-1]])
                idx = jnp.arange(T)
                seg_start = jnp.where(~same, idx, 0)
                seg_start = lax.associative_scan(jnp.maximum, seg_start)
                rank_sorted = idx - seg_start
                rank = jnp.zeros((T,), I32).at[order].set(
                    rank_sorted.astype(I32))
        tkt = jnp.where(need_ticket, rows.nt[bkey] + rank, NOTK)
        counts = _seg_sum(jnp.ones_like(bkey), bkey, R, need_ticket)
        rows = rows._replace(nt=rows.nt + counts)
        th = th._replace(
            ticket=th.ticket.at[tids, jnp.clip(th.op, 0, L - 1)].set(
                jnp.where(need_ticket, tkt, cur(th.ticket, th.op))),
            phase=jnp.where(need_ticket, WAIT, th.phase),
            wstart=jnp.where(need_ticket, now, th.wstart))

        # ------------------------------------------------ 9. hotspot detect
        # without a hotspot queue rows never turn hot, so the off branch
        # is the identity (runtime-skipped; select under vmap).
        def _hotspot_on(op):
            hot, gleader, gcount = op
            live3 = th.ticket >= 0
            d3_nwait = _seg_sum(jnp.ones_like(th.ticket), th.keys, R,
                                live3 & ~th.applied)
            d3_nlive = _seg_sum(jnp.ones_like(th.ticket), th.keys, R, live3)
            promote = d3_nwait > dp.hot_threshold
            # demote only when the row is fully quiesced: no waiter AND no
            # applied-uncommitted update (the dep list must be empty, §4.1)
            demote = hot & (d3_nlive == 0)
            return ((hot | promote) & ~demote,
                    jnp.where(demote, NOTK, gleader),
                    jnp.where(demote, 0, gcount))

        if "group_hotspot" in ablate:
            hot, gleader, gcount = rows.hot, rows.gleader, rows.gcount
        else:
            with jax.named_scope("stage_hotspot_detect"):
                hot, gleader, gcount = lax.cond(
                    dp.hot_queue, _hotspot_on, lambda op: op,
                    (rows.hot, rows.gleader, rows.gcount))
        rows = rows._replace(hot=hot, gleader=gleader, gcount=gcount)

        ev = StepEvents(
            t_pre=s.g.now, t_post=g.now, row_cur=cur_key, row_begin=bkey,
            grant=grantable, group_join=is_member_grant, timeout=to_fire,
            victim=victim, release=rel_now, commit=c_done,
            wait_enter=need_ticket, abort=r_done)
        return SimState(th, rows, g), ev

    return step


def _make_step(stat: StaticShape, dp: DynParams, until=None,
               ablate: frozenset = frozenset()):
    """Classic step: :func:`_make_step_events` minus the event tuple.

    All non-traced entry points route through this wrapper; XLA DCEs the
    dropped event masks (they are aliases of values the step computes
    anyway), so the split is free. ``ablate`` is the profiler seam
    (:data:`PROF_STAGES`) — production entry points leave it empty.
    """
    step_events = _make_step_events(stat, dp, until=until, ablate=ablate)
    return lambda s: step_events(s)[0]


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def init_state_dyn(stat: StaticShape, dp: DynParams) -> SimState:
    """Initial state at the padded shape; padded threads start in HALT."""
    T, L, R = stat.n_threads, stat.txn_len, stat.n_rows
    tids = jnp.arange(T, dtype=I32)
    th = Threads(
        phase=jnp.where(tids < dp.n_active, I32(START), I32(HALT)),
        work=jnp.zeros((T,), I32),
        op=jnp.zeros((T,), I32),
        txn=jnp.zeros((T,), I32),
        tstart=jnp.zeros((T,), I32),
        wstart=jnp.zeros((T,), I32),
        willab=jnp.zeros((T,), bool),
        forced=jnp.zeros((T,), bool),
        vabort=jnp.zeros((T,), bool),
        retry=jnp.zeros((T,), bool),
        keys=jnp.zeros((T, L), I32),
        iswr=jnp.zeros((T, L), bool),
        dup=jnp.zeros((T, L), bool),
        ticket=jnp.full((T, L), NOTK),
        applied=jnp.zeros((T, L), bool),
        early=jnp.zeros((T, L), bool),
        committing=jnp.zeros((T, L), bool),
        lastu=jnp.zeros((T, L), bool),
        released=jnp.zeros((T, L), bool),
        nops=jnp.full((T,), L, I32),
        detleft=jnp.zeros((T,), I32),
    )
    rows = Rows(
        nt=jnp.zeros((R,), I32),
        updating=jnp.zeros((R,), bool),
        hot=jnp.zeros((R,), bool),
        gleader=jnp.full((R,), NOTK),
        gcount=jnp.zeros((R,), I32),
        casc=jnp.full((R,), INF),
        batch_end=jnp.zeros((R,), I32),
        batch_n=jnp.zeros((R,), I32),
        applied_val=jnp.zeros((R,), I32),
        committed_val=jnp.zeros((R,), I32),
    )
    g = Globals(
        now=jnp.asarray(0, I32),
        commits=jnp.asarray(0, I32),
        user_aborts=jnp.asarray(0, I32),
        forced_aborts=jnp.asarray(0, I32),
        lock_ops=jnp.asarray(0, I32),
        wait_ticks=jnp.asarray(0.0, F32),
        busy_ticks=jnp.asarray(0.0, F32),
        lat_sum=jnp.asarray(0.0, F32),
        hist=jnp.zeros((N_HIST,), I32),
        dd_ticks=jnp.asarray(0, I32),
        iters=jnp.asarray(0, I32),
        tb=jnp.zeros((len(TB_BRANCHES), N_TB), I32),
        ca=jnp.zeros((N_CA, R), I32),
    )
    return SimState(th, rows, g)


def init_state(cfg: EngineConfig) -> SimState:
    """Initial state for a single (unpadded) config."""
    return init_state_dyn(*split_config(cfg))


def _run_core(stat: StaticShape, dp: DynParams, s0: SimState,
              until: jnp.ndarray | None = None) -> SimState:
    """The loop itself — shared verbatim by the jitted single-config entry
    point, the vmapped sweep entry point, and the segmented entry points
    (bitwise parity depends on it).

    ``until`` (traced, optional) pauses the loop at the segment boundary:
    it bounds the loop condition AND caps *idle* jumps (see
    :func:`_make_step`), so a stalled system pauses exactly at ``until``
    while a busy one pauses at its first event past it. Busy steps are
    never split, so a segmented run replays the single-shot step
    sequence literally — state and metrics are bit-identical, and even
    ``Globals.iters`` only differs when a fully-idle stall window spans
    boundaries (the jump splits into one iteration per segment).
    """
    step = _make_step(stat, dp, until=until)
    return lax.while_loop(_make_cond(dp, until=until), step, s0)


def _make_cond(dp: DynParams, until=None):
    """Loop condition shared by classic and traced runners (obs layer)."""
    stop_time = _stop_time(dp)

    def cond(s: SimState):
        live = (s.th.phase != HALT).any()
        running = jnp.where(dp.drain,
                            live & (s.g.now < stop_time),
                            s.g.now < dp.horizon)
        if until is not None:
            running = running & (s.g.now < until)
        return running & (s.g.iters < dp.max_iters)

    return cond


@functools.partial(jax.jit, static_argnums=0)
def _run_dyn(stat: StaticShape, dp: DynParams, s0: SimState) -> SimState:
    return _run_core(stat, dp, s0)


@functools.partial(jax.jit, static_argnums=0)
def _run_batch(stat: StaticShape, dps: DynParams, s0s: SimState) -> SimState:
    """Run G stacked configs as one program (leading axis on every leaf).

    ``lax.while_loop`` under vmap keeps stepping until every lane's cond is
    false, select-freezing finished lanes — so each lane's final state is
    bit-identical to running it alone at the same (padded) shape.

    Because ``s0s`` is an argument and the loop cond is a pure function of
    the state, this entry point is also *resumable*: passing a paused
    state continues the identical step sequence. The sweep compaction
    scheduler exploits this by capping ``dp.max_iters`` (traced — no
    recompile) at ``iters + slice`` per call, pausing lanes at iteration
    budgets and repacking the unfinished ones; the resulting final states
    are bit-identical to single-shot runs in EVERY leaf including the
    ``iters`` diagnostic (nothing about the orbit changes, only where it
    is observed).
    """
    return jax.vmap(lambda dp, s0: _run_core(stat, dp, s0))(dps, s0s)


def stop_ticks(cfg: EngineConfig) -> int:
    """Host mirror of :func:`_stop_time` for one config."""
    if cfg.drain:
        return cfg.horizon + 3 * max(cfg.protocol.wait_timeout, cfg.horizon)
    return cfg.horizon


def run_finished(cfg: EngineConfig, now: int, iters: int,
                 phase=None) -> bool:
    """Host mirror of :func:`_run_core`'s loop condition (negated).

    The compaction scheduler retires a paused lane exactly when the
    single-shot loop would have exited — keeping the retire decision in
    lockstep with the device cond is what makes compacted results
    bit-identical. ``phase`` (the (T,) thread-phase vector) is only needed
    for ``drain`` runs, whose cond also ends when every thread HALTs.
    """
    if iters >= cfg.max_iters:
        return True
    if cfg.drain:
        live = True if phase is None else bool((np.asarray(phase)
                                                != HALT).any())
        return (not live) or now >= stop_ticks(cfg)
    return now >= cfg.horizon


class SegSnapshot(NamedTuple):
    """Instantaneous contention telemetry at a segment boundary.

    Counter-style telemetry (throughput, aborts, latency, utilization)
    comes from differencing ``Globals`` across the boundary instead
    (:func:`repro.core.lock.metrics.delta_globals`); these are the
    *state* observables a governor cannot recover from counters.
    """
    max_qlen: jnp.ndarray   # () i32  longest row wait queue
    n_hot: jnp.ndarray      # () i32  rows currently promoted hot
    n_live: jnp.ndarray     # () i32  live tickets across all rows
    n_waiting: jnp.ndarray  # () i32  threads in a lock/commit wait phase
    # Distribution observables (obs layer): policies that only see maxima
    # cannot tell one pathological queue from uniform pressure. Both are
    # log2-bucket histograms (bucket 0 = empty, b >= 1 = [2**(b-1), 2**b)):
    wait_hist: jnp.ndarray  # (N_QHIST,) rows by wait-queue depth (sums to R)
    occ_hist: jnp.ndarray   # (N_QHIST,) HOT rows by live-ticket occupancy
    #                         (sums to n_hot)


def _q_bucket(v):
    """log2 occupancy bucket: 0 -> 0, 1 -> 1, [2,4) -> 2, [4,8) -> 3, ..."""
    f = jnp.log2(jnp.maximum(v, 1).astype(F32))
    return jnp.clip(jnp.where(v <= 0, 0, f.astype(I32) + 1), 0, N_QHIST - 1)


def _snapshot(stat: StaticShape, dp: DynParams, s: SimState) -> SegSnapshot:
    d = _derive(stat, dp, s.th, s.rows)
    waitish = ((s.th.phase == WAIT) | (s.th.phase == CWAIT)
               | (s.th.phase == RBWAIT))
    return SegSnapshot(
        max_qlen=d.n_wait.max().astype(I32),
        n_hot=s.rows.hot.sum().astype(I32),
        n_live=d.n_live.sum().astype(I32),
        n_waiting=waitish.sum().astype(I32),
        wait_hist=jnp.zeros((N_QHIST,), I32).at[_q_bucket(d.n_wait)].add(1),
        occ_hist=jnp.zeros((N_QHIST,), I32).at[_q_bucket(d.n_live)].add(
            jnp.where(s.rows.hot, 1, 0)))


def _run_seg_core(stat: StaticShape, dp: DynParams, s0: SimState,
                  until: jnp.ndarray) -> tuple[SimState, SegSnapshot]:
    s = _run_core(stat, dp, s0, until=until)
    return s, _snapshot(stat, dp, s)


@functools.partial(jax.jit, static_argnums=0)
def _run_seg_dyn(stat: StaticShape, dp: DynParams, s0: SimState,
                 until: jnp.ndarray) -> tuple[SimState, SegSnapshot]:
    return _run_seg_core(stat, dp, s0, until)


@functools.partial(jax.jit, static_argnums=0)
def _run_seg_batch(stat: StaticShape, dps: DynParams, s0s: SimState,
                   untils: jnp.ndarray) -> tuple[SimState, SegSnapshot]:
    """Segmented analogue of :func:`_run_batch`: G lanes, one program.

    Every argument including ``untils`` is traced, so a governor can
    re-decide any lane's protocol, workload, or boundary between segments
    and re-enter the *same* executable — zero recompiles per shape bucket.
    """
    return jax.vmap(
        lambda dp, s0, u: _run_seg_core(stat, dp, s0, u))(dps, s0s, untils)


def run_segment(stat: StaticShape, dp: DynParams, state: SimState,
                until) -> tuple[SimState, SegSnapshot]:
    """Advance ``state`` until sim-time reaches ``until`` (or the run ends).

    Returns the resumable state plus an end-of-segment telemetry snapshot.
    A run split into N segments with unchanged ``dp`` is bit-identical to
    the single-shot :func:`run_sim`/``simulate`` result in every state
    leaf and metric — the boundary pauses the ``while_loop`` between
    events (busy systems stop at their first event past ``until``, fully
    stalled ones exactly at it); it never moves or splits an event. The
    diagnostic ``Globals.iters`` can differ only when a stall window
    spans boundaries. Changing ``dp`` (protocol preset, costs, workload)
    between segments is free: everything in it is traced, so the
    compiled program is reused.
    """
    return _run_seg_dyn(stat, dp, state, jnp.asarray(until, I32))


def run_sim(cfg: EngineConfig) -> SimState:
    """Run a simulation to completion and return the final state."""
    stat, dp = split_config(cfg)
    return _run_dyn(stat, dp, init_state_dyn(stat, dp))


def simulate(protocol: str, workload: WorkloadSpec, n_threads: int,
             costs: CostModel | None = None, horizon: int = 2_000_000,
             p_abort: float = 0.0, drain: bool = False, seed: int = 0,
             attrib: bool = False, **proto_over) -> SimState:
    """Convenience entry point: run one protocol over one workload."""
    cfg = EngineConfig(
        protocol=protocol_params(protocol, **proto_over),
        costs=costs or CostModel(),
        workload=workload,
        n_threads=n_threads,
        horizon=horizon,
        p_abort=p_abort,
        drain=drain,
        seed=seed,
        attrib=attrib,
    )
    return run_sim(cfg)
