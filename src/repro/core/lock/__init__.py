"""Concurrency-control engine: the paper's faithful reproduction layer."""
from . import chop
from .chop import ChopPlan
from .costs import CostModel, ProtocolParams, protocol_params, PROTOCOLS
from .workload import (WorkloadSpec, DynWorkload, dyn_workload, zipf_cdf,
                       zipf_cdf_table, DriftSchedule, DRIFT_KINDS,
                       stationary, hot_migration, skew_ramp, flash_crowd)
from .engine import (EngineConfig, StaticShape, DynParams, split_config,
                     SimState, SegSnapshot, StepEvents, init_state,
                     init_state_dyn, run_sim, run_segment, simulate,
                     N_TB, TB_NAMES, TB_BRANCHES, N_QHIST,
                     START, WAIT, EXEC, CWAIT, COMMIT, RBACK, RBWAIT,
                     BACKOFF, ARRIVE, HALT)
from .metrics import (SimResult, extract, extract_segment, delta_globals,
                      CSV_HEADER, TICKS_PER_SEC)
from .aria import simulate_aria, extract_aria

__all__ = [
    "chop", "ChopPlan",
    "CostModel", "ProtocolParams", "protocol_params", "PROTOCOLS",
    "WorkloadSpec", "DynWorkload", "dyn_workload", "zipf_cdf",
    "zipf_cdf_table", "DriftSchedule", "DRIFT_KINDS", "stationary",
    "hot_migration", "skew_ramp", "flash_crowd",
    "EngineConfig", "StaticShape", "DynParams", "split_config",
    "SimState", "SegSnapshot", "StepEvents", "init_state", "init_state_dyn",
    "run_sim", "run_segment", "simulate",
    "N_TB", "TB_NAMES", "TB_BRANCHES", "N_QHIST",
    "SimResult", "extract", "extract_segment", "delta_globals",
    "CSV_HEADER", "TICKS_PER_SEC",
    "simulate_aria", "extract_aria",
]
