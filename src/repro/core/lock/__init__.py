"""Concurrency-control engine: the paper's faithful reproduction layer."""
from .costs import CostModel, ProtocolParams, protocol_params, PROTOCOLS
from .workload import WorkloadSpec, zipf_cdf
from .engine import (EngineConfig, SimState, init_state, run_sim, simulate,
                     START, WAIT, EXEC, CWAIT, COMMIT, RBACK, RBWAIT,
                     BACKOFF, ARRIVE, HALT)
from .metrics import SimResult, extract, CSV_HEADER, TICKS_PER_SEC
from .aria import simulate_aria, extract_aria

__all__ = [
    "CostModel", "ProtocolParams", "protocol_params", "PROTOCOLS",
    "WorkloadSpec", "zipf_cdf",
    "EngineConfig", "SimState", "init_state", "run_sim", "simulate",
    "SimResult", "extract", "CSV_HEADER", "TICKS_PER_SEC",
]
