"""Concurrency-control engine: the paper's faithful reproduction layer."""
from .costs import CostModel, ProtocolParams, protocol_params, PROTOCOLS
from .workload import (WorkloadSpec, DynWorkload, dyn_workload, zipf_cdf,
                       zipf_cdf_table)
from .engine import (EngineConfig, StaticShape, DynParams, split_config,
                     SimState, init_state, init_state_dyn, run_sim, simulate,
                     START, WAIT, EXEC, CWAIT, COMMIT, RBACK, RBWAIT,
                     BACKOFF, ARRIVE, HALT)
from .metrics import SimResult, extract, CSV_HEADER, TICKS_PER_SEC
from .aria import simulate_aria, extract_aria

__all__ = [
    "CostModel", "ProtocolParams", "protocol_params", "PROTOCOLS",
    "WorkloadSpec", "DynWorkload", "dyn_workload", "zipf_cdf",
    "zipf_cdf_table",
    "EngineConfig", "StaticShape", "DynParams", "split_config",
    "SimState", "init_state", "init_state_dyn", "run_sim", "simulate",
    "SimResult", "extract", "CSV_HEADER", "TICKS_PER_SEC",
    "simulate_aria", "extract_aria",
]
