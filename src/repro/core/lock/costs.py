"""Cycle cost model + per-protocol parameters for the CC engine.

Units: 1 tick = 0.1 microseconds. All costs are integer ticks.

The constants are calibrated (see benchmarks/) so the *shape* of every paper
figure reproduces: serial hotspot ~60k TPS, MySQL-at-1024-threads collapsing
below serial (Fig. 2a), O2 removing the deadlock-detection term, group
locking removing the per-update lock+commit serialization (Fig. 3), group
commit amortizing the replication sync (Fig. 5c).

Cost semantics (where each cost lands):
  - ``grant_overhead`` is paid on the *row's serial path* when a waiter is
    granted (it models the lock-manager bucket mutex work: lock record
    creation + deadlock detection scan, which the paper observes blocks
    other transactions on the same row/page).
  - deadlock detection cost is ``dd_coeff * queue_len`` ticks, added to the
    grant overhead (Fig. 2a's pathology: cost grows with the queue).
  - commit pays ``commit_base`` plus the replication sync latency
    (``sync_lat``); with group commit, members joining an in-flight batch
    complete with the batch (Fig. 5c).
"""
from __future__ import annotations

import dataclasses

PROTOCOLS = ("mysql", "o1", "o2", "group", "bamboo",
             "brook2pl")  # + "aria" (own module)


@dataclasses.dataclass(frozen=True)
class ProtocolParams:
    name: str
    # --- lock manager ---
    lock_base: int = 10          # lock record create/acquire (ticks)
    grant_cost: int = 2          # waking/granting a queued txn
    dd_coeff: float = 3.0        # deadlock-detection ticks per queued txn
    has_detection: bool = True   # 2-cycle waits-for detection active
    # --- hot-row handling ---
    hot_queue: bool = False      # O2/group: hot rows use the hotspot queue
    early_release: bool = False  # grant successor at update completion (hot)
    early_all: bool = False      # bamboo: early release on every row
    group_lock: bool = False     # leader/follower group locking
    group_commit: bool = False   # batch commit-phase sync within a group
    dynamic_batch: bool = True   # §4.6.1 dynamic batch size
    batch_size: int = 10         # group batch size (B)
    hot_threshold: int = 32      # §4.1 promotion threshold
    proactive_abort: bool = False  # §4.5 hot+non-hot proactive rollback
    # --- Brook-2PL (chop.py static analysis; deadlock-free 2PL) ---
    ordered_acquire: bool = False  # acquire rows in canonical chop order
    per_op_release: bool = False   # retire tickets at their last-use op
    # --- timeouts (ticks); <=0 disables ---
    wait_timeout: int = 500_000      # 50ms
    commit_wait_timeout: int = 500_000


@dataclasses.dataclass(frozen=True)
class CostModel:
    op_exec: int = 50            # row update work (5us: index lookup+apply)
    read_exec: int = 20          # snapshot read
    commit_base: int = 100       # commit bookkeeping (10us)
    sync_lat: int = 0            # replication sync latency (ticks); Fig 9
    rb_base: int = 80            # rollback fixed cost
    rb_per_op: int = 40          # per applied-op undo cost
    backoff: int = 200           # retry backoff after forced abort
    queue_insert: int = 3        # enqueue into hotspot queue (off crit path)
    arrival_rate: float = 0.0    # fixed-TPS model: txns/tick; 0 = closed loop
    # multi-row cascades can form rollback-order cycles (the multi-hot-row
    # deadlock the paper excludes, §6.5); a stuck rollback proceeds out of
    # order after this many ticks (value semantics commute, so the
    # serializability counter invariant is preserved).
    rb_turn_timeout: int = 20_000


def protocol_params(name: str, **over) -> ProtocolParams:
    base = {
        "mysql": dict(lock_base=12, dd_coeff=3.0, has_detection=True),
        "o1": dict(lock_base=4, dd_coeff=1.0, has_detection=True),
        "o2": dict(lock_base=4, dd_coeff=0.0, has_detection=False,
                   hot_queue=True),
        "group": dict(lock_base=4, dd_coeff=0.0, has_detection=False,
                      hot_queue=True, early_release=True, group_lock=True,
                      group_commit=True, proactive_abort=True),
        "bamboo": dict(lock_base=8, dd_coeff=1.0, has_detection=True,
                       early_all=True, early_release=True),
        # Brook-2PL: chop-ordered acquisition makes waits-for cycles
        # structurally impossible, so BOTH dynamic deadlock resolvers are
        # off — no detection walk (dd_coeff 0) and no lock-wait timeouts
        # (0 disables; a timeout would be the residual deadlock resolver
        # and its absence is the protocol's claim). Per-op release
        # shrinks hold intervals to [acquire, last-use].
        "brook2pl": dict(lock_base=4, dd_coeff=0.0, has_detection=False,
                         ordered_acquire=True, per_op_release=True,
                         wait_timeout=0, commit_wait_timeout=0),
    }[name]
    base.update(over)
    return ProtocolParams(name=name, **base)
