"""Result extraction for CC-engine simulations.

Two modes:

* whole-run: :func:`extract` / :func:`extract_globals` on a final state.
* per-segment (delta): every metric in ``Globals`` is a monotone counter
  (or a histogram of counters), so the metrics of any time window are the
  elementwise difference of its boundary snapshots — :func:`delta_globals`
  builds that difference as a synthetic ``Globals`` whose ``now`` is the
  window length, and :func:`extract_segment` feeds it through the same
  extraction path, keeping whole-run and per-segment numbers structurally
  identical (a 1-segment window reproduces the whole-run result exactly).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .engine import (SimState, N_HIST, HIST_BASE, TB_NAMES, CA_NAMES,
                     CA_WAIT, CA_GRANTS)

TICKS_PER_SEC = 10_000_000  # 1 tick = 0.1us


@dataclasses.dataclass
class SimResult:
    protocol: str
    n_threads: int
    commits: int
    user_aborts: int
    forced_aborts: int
    lock_ops: int
    sim_seconds: float
    tps: float
    mean_latency_us: float
    p95_latency_us: float
    p99_latency_us: float
    lock_wait_frac: float       # share of txn time spent lock-waiting
    cpu_util: float             # busy thread-ticks / (T * ticks)
    abort_rate: float
    iters: int
    # deadlock-detection ticks paid on the grant path (0 for detection-
    # free protocols; brook2pl's acceptance metric). Defaulted so pre-PR5
    # Globals snapshots (no dd_ticks leaf) still extract.
    dd_ticks: int = 0
    # TickBreakdown (obs layer, DESIGN.md §11): thread-tick attribution
    # {bin_name: ticks} summed over branches, and the hot-branch share
    # alone. sum(breakdown.values()) == T * now ticks (conservation).
    # Defaulted empty so pre-PR7 Globals snapshots (no tb leaf) extract.
    breakdown: dict = dataclasses.field(default_factory=dict)
    breakdown_hot: dict = dataclasses.field(default_factory=dict)
    # Per-record contention summary (obs layer, DESIGN.md §14): top-K rows
    # of ``Globals.ca`` by wait ticks, as {"row": r, "wait_ticks": ...,
    # "grants": ..., "timeouts": ..., "victims": ..., "queue_sum": ...,
    # "queue_max": ...} dicts. Empty when attribution is off (the
    # accumulator is all-zero) or on pre-PR10 Globals snapshots (no ca
    # leaf), so old stores keep extracting.
    hotspots: list = dataclasses.field(default_factory=list)

    def row(self) -> str:
        return (f"{self.protocol},{self.n_threads},{self.tps:.0f},"
                f"{self.mean_latency_us:.1f},{self.p95_latency_us:.1f},"
                f"{self.abort_rate:.4f},{self.lock_ops},"
                f"{self.cpu_util:.3f},{self.lock_wait_frac:.3f}")


def _pct_from_hist(hist: np.ndarray, q: float) -> float:
    total = hist.sum()
    if total == 0:
        return 0.0
    target = q * total
    cum = np.cumsum(hist)
    b = int(np.searchsorted(cum, target))
    b = min(b, N_HIST - 1)
    # bucket b holds latencies in [base^b - 1, base^(b+1) - 1) ticks
    ticks = HIST_BASE ** (b + 0.5)
    return ticks / 10.0  # -> us


def hotspot_rows(ca, top_k: int = 8) -> list[dict]:
    """Top-``top_k`` contended records from a ``Globals.ca`` accumulator
    (or a :func:`delta_globals` window of one), ranked by wait ticks with
    grant count as the tiebreak. Rows with no recorded activity are
    dropped, so attribution-off runs summarize to ``[]``."""
    ca = np.asarray(ca)
    active = ca.any(axis=0)
    if not active.any():
        return []
    rank = np.lexsort((-ca[CA_GRANTS], -ca[CA_WAIT]))[:top_k]
    return [
        {"row": int(r), **{k: int(ca[i, r]) for i, k in enumerate(CA_NAMES)}}
        for r in rank if active[r]
    ]


def extract(protocol: str, n_threads: int, s: SimState) -> SimResult:
    return extract_globals(protocol, n_threads, s.g)


def extract_globals(protocol: str, n_threads: int, g) -> SimResult:
    """Extract from the Globals leaf alone (all metrics live there) — the
    sweep runner uses this to avoid hauling full states off device."""
    commits = int(g.commits)
    aborts = int(g.user_aborts) + int(g.forced_aborts)
    now = max(int(g.now), 1)
    sim_s = now / TICKS_PER_SEC
    hist = np.asarray(g.hist)
    tb = getattr(g, "tb", None)
    if tb is not None:
        tb = np.asarray(tb)
        breakdown = {k: int(tb[:, i].sum()) for i, k in enumerate(TB_NAMES)}
        breakdown_hot = {k: int(tb[1, i]) for i, k in enumerate(TB_NAMES)}
    else:                       # pre-PR7 Globals snapshot
        breakdown, breakdown_hot = {}, {}
    lat_mean = (float(g.lat_sum) / commits / 10.0) if commits else 0.0
    total_lat_ticks = max(float(g.lat_sum), 1.0)
    return SimResult(
        protocol=protocol,
        n_threads=n_threads,
        commits=commits,
        user_aborts=int(g.user_aborts),
        forced_aborts=int(g.forced_aborts),
        lock_ops=int(g.lock_ops),
        sim_seconds=sim_s,
        tps=commits / sim_s,
        mean_latency_us=lat_mean,
        p95_latency_us=_pct_from_hist(hist, 0.95),
        p99_latency_us=_pct_from_hist(hist, 0.99),
        lock_wait_frac=float(g.wait_ticks) / total_lat_ticks,
        cpu_util=float(g.busy_ticks) / (n_threads * now),
        abort_rate=aborts / max(commits + aborts, 1),
        iters=int(g.iters),
        dd_ticks=int(getattr(g, "dd_ticks", 0)),
        breakdown=breakdown,
        breakdown_hot=breakdown_hot,
        hotspots=(hotspot_rows(ca) if (ca := getattr(g, "ca", None))
                  is not None else []),
    )


def delta_globals(g0, g1):
    """Counter delta across a segment ``[g0, g1]`` as a synthetic Globals.

    Every field of ``Globals`` is a monotone counter over the run, so the
    segment's contribution is ``g1 - g0`` fieldwise; ``now`` becomes the
    window length, which makes the result directly consumable by
    :func:`extract_globals` (tps/cpu_util divide by the window). Works on
    device arrays and on host (numpy) snapshots alike. One caveat: the
    ``ca[CA_QMAX]`` lane of the contention accumulator is a running max,
    not a counter — its delta is the window's *peak increase* (0 unless
    the row set a new all-run queue-depth record inside the window), not
    the window max; every other ca lane differences exactly.
    """
    return type(g1)(*(b - a for a, b in zip(g0, g1)))


def extract_segment(protocol: str, n_threads: int, g0, g1) -> SimResult:
    """Per-segment metrics from boundary Globals snapshots (see above)."""
    return extract_globals(protocol, n_threads, delta_globals(g0, g1))


CSV_HEADER = ("protocol,threads,tps,mean_lat_us,p95_lat_us,abort_rate,"
              "lock_ops,cpu_util,lock_wait_frac")


def bench_row(name: str, wall_us: float, r: SimResult) -> str:
    """The benchmark harness's ``name,us_per_call,derived`` row — shared by
    the per-config path (benchmarks.common.cc_point) and the sweep path
    (repro.sweep.summarize) so the two dialects can't drift apart."""
    return (f"{name},{wall_us:.0f},"
            f"tps={r.tps:.0f};p95us={r.p95_latency_us:.0f}"
            f";abort={r.abort_rate:.3f};lockops={r.lock_ops}"
            f";cpu={r.cpu_util:.2f};waitfrac={r.lock_wait_frac:.2f}")
