"""Workload generators for the concurrency-control engine.

Each workload kind maps a (thread, txn_counter, op_slot) triple to a row key
and a read/write flag, deterministically, via an integer hash. This keeps the
engine allocation-free: transactions are (re)generated on the fly when a
thread starts (or retries) a transaction.

Workload kinds (mirroring the paper's §6.1.1):
  - ``hotspot_update``  SysBench hotspot update: op 0 writes THE hot row
                        (key 0); remaining ops hit non-hot keys.
  - ``hotspot_mix``     SysBench hotspot read/write: Zipf(SF) keys, RW mix.
  - ``hotspot_scan``    updates dispersed over a small warm set (paper's
                        multi-hotspot dispersion case).
  - ``uniform``         uniform keys, RW mix (uniform update / read-only).
  - ``zipf``            Zipf(SF) keys, all writes (skewness experiment).
  - ``fit``             FiT-like: op 0 writes a hot account row (Zipf over a
                        small hot set), op 1 writes a uniform non-hot row
                        (transaction-record insert).
  - ``tpcc``            TPC-C-like: op 0 writes warehouse row (W rows),
                        op 1 writes district row (10 per warehouse),
                        remaining ops mixed uniform (stock/customer).

Traceability (DESIGN.md §3.1): everything *value-like* about a workload —
write ratio, hot-set size, seed, the Zipf CDF table, the active txn length —
lives in :class:`DynWorkload`, a NamedTuple of jnp scalars (plus the (R,)
CDF array) that the sweep subsystem stacks along a config axis and feeds
through ``jax.vmap``. Only the *shape-like* facts stay static: the kind
string, ``n_rows`` (R), and the padded slot count L. The Zipf CDF is
computed **eagerly** (outside any jit) so a vmapped lane and a per-config
run consume bit-identical tables.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np
import jax.numpy as jnp

from . import chop

I32 = jnp.int32
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    kind: str = "hotspot_update"
    n_rows: int = 8192          # key space (R)
    txn_len: int = 1            # ops per transaction (TL)
    write_ratio: float = 1.0    # fraction of non-structural ops that write
    zipf_s: float = 0.7         # skew factor (SF)
    n_hot: int = 4              # hot-set size for fit/hotspot_scan
    n_warehouses: int = 1       # tpcc
    seed: int = 0
    reads_lock: bool = False    # SER current reads (locks for reads)
    hot_base: int = 0           # hot-set anchor key (drift: migration)

    def __post_init__(self):
        assert self.txn_len >= 1
        assert self.kind in (
            "hotspot_update", "hotspot_mix", "hotspot_scan",
            "uniform", "zipf", "fit", "tpcc",
        )


class DynWorkload(NamedTuple):
    """Traceable (vmap-stackable) view of a WorkloadSpec.

    All fields are jnp scalars except ``zcdf`` (the (R,) Zipf CDF table).
    The workload *kind* and the key-space size R stay static — they pick
    the compiled program; everything here only feeds it data.
    """
    txn_len: jnp.ndarray        # () i32 — ACTIVE ops per txn (<= padded L)
    write_ratio: jnp.ndarray    # () f32
    n_hot: jnp.ndarray          # () i32
    n_warehouses: jnp.ndarray   # () i32
    seed: jnp.ndarray           # () i32
    reads_lock: jnp.ndarray     # () bool
    hot_base: jnp.ndarray       # () i32 hot-set anchor (0 = classic layout)
    zcdf: jnp.ndarray           # (R,) f32 Zipf CDF (always present)
    acq_rank: jnp.ndarray       # (R,) i32 chop lock-acquisition rank


def dyn_workload(spec: WorkloadSpec) -> DynWorkload:
    """Materialize the traceable view. Eager — call outside jit."""
    return DynWorkload(
        txn_len=jnp.asarray(spec.txn_len, I32),
        write_ratio=jnp.asarray(spec.write_ratio, F32),
        n_hot=jnp.asarray(spec.n_hot, I32),
        n_warehouses=jnp.asarray(spec.n_warehouses, I32),
        seed=jnp.asarray(spec.seed, I32),
        reads_lock=jnp.asarray(spec.reads_lock, bool),
        hot_base=jnp.asarray(spec.hot_base, I32),
        zcdf=zipf_cdf_table(spec.n_rows, spec.zipf_s),
        acq_rank=chop.acquisition_rank(spec),
    )


# ---------------------------------------------------------------------------
# integer hashing (splitmix32-style) — cheap, deterministic, vectorizable
# ---------------------------------------------------------------------------

def _hash_u32(x: jnp.ndarray) -> jnp.ndarray:
    """splitmix32 finalizer over uint32."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _hash3(a, b, c, salt) -> jnp.ndarray:
    salt = jnp.asarray(salt).astype(jnp.uint32)
    h = _hash_u32(a.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + salt)
    h = _hash_u32(h ^ (b.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)))
    h = _hash_u32(h ^ (c.astype(jnp.uint32) * jnp.uint32(0xC2B2AE35)))
    return h


def _uniform01(h: jnp.ndarray) -> jnp.ndarray:
    return h.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)


def zipf_cdf(n: int, s: float) -> np.ndarray:
    """CDF of a Zipf(s) distribution over keys [0, n) (numpy, float64).

    Weights come from ``chop.zipf_weights`` — the single definition the
    chop heat model also ranks by, so the acquisition order can never
    diverge from the keys actually drawn."""
    w = chop.zipf_weights(n, s)
    cdf = np.cumsum(w / w.sum())
    cdf[-1] = 1.0
    return cdf.astype(np.float32)


def zipf_cdf_table(n: int, s: float) -> jnp.ndarray:
    """Engine-facing CDF table, (R,) f32 on device.

    Deliberately routed through the single numpy implementation so every
    consumer (per-config run, sweep lane, aria batch) sees bit-identical
    tables regardless of batching.
    """
    return jnp.asarray(zipf_cdf(n, float(s)))


# ---------------------------------------------------------------------------
# transaction generation
# ---------------------------------------------------------------------------

def gen_txn_dyn(kind: str, n_rows: int, L: int, dw: DynWorkload,
                thread_ids: jnp.ndarray, txn_ctr: jnp.ndarray,
                acq_order: jnp.ndarray | None = None,
                skip_analysis: bool = False):
    """Generate transaction programs for every thread (traceable params).

    Args:
      kind: workload kind (static — selects the program).
      n_rows: key space R (static).
      L: padded op-slot count (static shape). Slots >= ``dw.txn_len`` are
         generated but never executed (``nops`` stops the engine first).
      dw: traceable workload parameters.
      thread_ids: (T,) int32.
      txn_ctr: (T,) int32 per-thread transaction counter.
      acq_order: optional traced bool (``DynParams.ordered_acquire``):
         re-sort each txn's active ops into the canonical chop rank
         order (``dw.acq_rank``) BEFORE the dup/re-entrancy analysis, so
         Brook-2PL lanes acquire rows in one global order. False (or
         None) leaves programs bit-identical to the classic layout.
      skip_analysis: static profiler seam (engine.PROF_STAGES
         "dup_analysis"): replace the (T, L, L) pairwise dup/last-use
         scan with its txn_len==1 closed form (dup never, every active
         slot is its key's last use) — exact at L == 1, DCEs the
         pairwise tensor otherwise. Production callers leave it False.

    Returns:
      keys:  (T, L) int32 row keys.
      iswr:  (T, L) bool write flags.
      dup:   (T, L) bool — key already appears earlier in the same txn
             (re-entrant access: no new ticket needed).
      lastu: (T, L) bool — slot is the LAST active slot touching its key
             (the per-op release point, chop.py §9.3; shares the dup
             analysis's pairwise key-equality tensor).
      nops:  (T,) int32 — ops in this txn (== dw.txn_len).
    """
    T = thread_ids.shape[0]
    tid = thread_ids[:, None]
    ctr = txn_ctr[:, None]
    slot = jnp.arange(L, dtype=I32)[None, :]

    base = tid * I32(1_000_003) + ctr
    hk = _hash3(base, slot, jnp.zeros_like(slot), dw.seed * 7 + 1)
    hw = _hash3(base, slot, jnp.ones_like(slot), dw.seed * 7 + 2)
    u_key = _uniform01(hk)
    u_wr = _uniform01(hw)

    R = n_rows

    def zipf_keys(u):
        return jnp.searchsorted(dw.zcdf, u).astype(I32).clip(0, R - 1)

    def uniform_keys(u, lo=0, hi=None):
        hi = R if hi is None else hi
        return (lo + (u * (hi - lo)).astype(I32)).clip(lo, hi - 1)

    wr = u_wr < dw.write_ratio

    # Hot-set migration (drift schedules): ``hot_base`` relocates the hot
    # keys. Every use below is the identity at hot_base=0, so classic
    # (non-drifting) workloads are bit-for-bit unchanged.
    hb = dw.hot_base % I32(R)

    if kind == "hotspot_update":
        # op 0: THE hot row (hot_base); others: uniform non-hot. The rest
        # keys dodge the hot key by swapping it with key 0 (the hot home).
        k_rest = uniform_keys(u_key, lo=1)
        k_rest = jnp.where(k_rest == hb, I32(0), k_rest)
        keys = jnp.where(slot == 0, hb, k_rest)
        iswr = jnp.where(slot == 0, True, wr)
    elif kind == "hotspot_mix":
        # zipf ranks rotate by hot_base: rank 0 (the hottest key) sits AT
        # hot_base, so migration moves the whole skew profile.
        keys = (zipf_keys(u_key) + hb) % I32(R)
        iswr = wr
    elif kind == "hotspot_scan":
        keys = (uniform_keys(u_key, lo=0, hi=jnp.maximum(dw.n_hot * 16, 2))
                + hb) % I32(R)
        iswr = jnp.ones_like(wr)
    elif kind == "uniform":
        keys = uniform_keys(u_key)
        iswr = wr
    elif kind == "zipf":
        keys = (zipf_keys(u_key) + hb) % I32(R)
        iswr = jnp.ones_like(wr)
    elif kind == "fit":
        # op 0: hot account (zipf over n_hot at hot_base); op 1: uniform
        # insert; rest mix. A migrated hot set may overlap the insert
        # range — that's the drift scenario's point (hot meets non-hot).
        hot = (uniform_keys(u_key, lo=0, hi=dw.n_hot) + hb) % I32(R)
        rest = uniform_keys(u_key, lo=dw.n_hot)
        keys = jnp.where(slot == 0, hot, rest)
        iswr = jnp.where(slot <= 1, True, wr)
    elif kind == "tpcc":
        W = dw.n_warehouses
        wh = uniform_keys(u_key, lo=0, hi=W)
        dist = W + wh * 10 + uniform_keys(u_wr, lo=0, hi=10)
        rest = uniform_keys(u_key, lo=W * 11)
        keys = jnp.where(slot == 0, wh, jnp.where(slot == 1, dist, rest))
        iswr = jnp.where(slot <= 1, True, wr)
    else:  # pragma: no cover
        raise ValueError(kind)

    iswr = iswr | dw.reads_lock

    if acq_order is not None:
        # Brook-2PL chop ordering (chop.py): canonical per-key rank,
        # traced select so one compiled step serves ordered + classic.
        keys, iswr = chop.apply_acquisition_order(
            dw.acq_rank, keys, iswr, dw.txn_len, acq_order)

    active = slot < dw.txn_len                           # (1, L)
    if skip_analysis:
        dup = jnp.zeros_like(iswr)
        lastu = jnp.broadcast_to(active, iswr.shape)
    else:
        # dup[i] = key i seen at an earlier slot (re-entrant lock).
        eq = keys[:, :, None] == keys[:, None, :]        # (T, L, L)
        earlier = jnp.tril(jnp.ones((L, L), dtype=bool), k=-1)[None]
        dup = jnp.any(eq & earlier & iswr[:, None, :], axis=2) & iswr
        # A read slot never takes a ticket; only writes matter for dup.

        # lastu[i] = no LATER active slot touches key i (the per-op
        # release point, == chop.last_use; reuses the eq tensor).
        later = jnp.triu(jnp.ones((L, L), dtype=bool), k=1)[None]
        lastu = active & ~jnp.any(eq & later & active[:, None, :], axis=2)

    nops = jnp.broadcast_to(dw.txn_len, (T,)).astype(I32)
    return keys.astype(I32), iswr, dup, lastu, nops


def gen_txn(spec: WorkloadSpec, thread_ids: jnp.ndarray, txn_ctr: jnp.ndarray):
    """Static-spec convenience wrapper around :func:`gen_txn_dyn`."""
    return gen_txn_dyn(spec.kind, spec.n_rows, spec.txn_len,
                       dyn_workload(spec), thread_ids, txn_ctr)


def will_abort_dyn(seed: jnp.ndarray, p_abort: jnp.ndarray,
                   thread_ids: jnp.ndarray,
                   txn_ctr: jnp.ndarray) -> jnp.ndarray:
    """Deterministic per-transaction injected-abort decision (Fig. 10).

    ``p_abort`` is a traced f32 scalar; 0 simply draws no aborts, so the
    same compiled program covers every injection rate in a sweep.
    """
    h = _hash3(thread_ids * I32(1_000_003) + txn_ctr,
               jnp.zeros_like(thread_ids), jnp.zeros_like(thread_ids),
               seed * 7 + 5)
    return _uniform01(h) < p_abort


def will_abort(spec: WorkloadSpec, p_abort: float,
               thread_ids: jnp.ndarray, txn_ctr: jnp.ndarray) -> jnp.ndarray:
    """Static-spec convenience wrapper around :func:`will_abort_dyn`."""
    if p_abort <= 0.0:
        return jnp.zeros_like(thread_ids, dtype=bool)
    return will_abort_dyn(jnp.asarray(spec.seed, I32),
                          jnp.asarray(p_abort, F32), thread_ids, txn_ctr)


# ---------------------------------------------------------------------------
# drift schedules (non-stationary workloads)
# ---------------------------------------------------------------------------
# A drift schedule is a per-segment sequence of WorkloadSpecs sharing one
# compile key (same kind / n_rows / txn_len): only DynWorkload VALUES change
# segment-to-segment, so the segmented engine replays the same executable
# under every drift — the property the adaptive governor builds on.

@dataclasses.dataclass(frozen=True)
class DriftSchedule:
    """A named per-segment workload sequence with a stable compile key."""
    name: str
    specs: tuple          # one WorkloadSpec per segment

    def __post_init__(self):
        assert self.specs, "empty drift schedule"
        k0 = (self.specs[0].kind, self.specs[0].n_rows, self.specs[0].txn_len)
        for s in self.specs:
            assert (s.kind, s.n_rows, s.txn_len) == k0, (
                "drift must keep the compile key (kind, n_rows, txn_len) "
                f"stable: {k0} vs {(s.kind, s.n_rows, s.txn_len)}")

    @property
    def n_segments(self) -> int:
        return len(self.specs)

    def spec(self, k: int) -> WorkloadSpec:
        """Workload for segment k (clamped — schedules are extendable)."""
        return self.specs[min(k, len(self.specs) - 1)]

    @property
    def base(self) -> WorkloadSpec:
        return self.specs[0]


def stationary(base: WorkloadSpec, n_segments: int,
               name: str = "stationary") -> DriftSchedule:
    """No drift — the control schedule."""
    return DriftSchedule(name, (base,) * n_segments)


def hot_migration(base: WorkloadSpec, n_segments: int, *, n_sites: int = 4,
                  period: int = 2) -> DriftSchedule:
    """The hot set jumps between ``n_sites`` evenly spaced anchor keys
    every ``period`` segments (shifting-hotspot regime, Guo et al.)."""
    stride = max(base.n_rows // max(n_sites, 1), 1)
    specs = tuple(
        dataclasses.replace(
            base, hot_base=((k // max(period, 1)) % n_sites) * stride)
        for k in range(n_segments))
    return DriftSchedule("hot_migration", specs)


def skew_ramp(base: WorkloadSpec, n_segments: int, *, lo: float = 0.3,
              hi: float = 1.0) -> DriftSchedule:
    """Access skew ramps linearly lo -> hi over the run (Zipf s drift)."""
    den = max(n_segments - 1, 1)
    specs = tuple(
        dataclasses.replace(base, zipf_s=lo + (hi - lo) * k / den)
        for k in range(n_segments))
    return DriftSchedule("skew_ramp", specs)


def flash_crowd(base: WorkloadSpec, n_segments: int, *, at: float = 0.5,
                write_lo: float = 0.15, write_hi: float = 1.0,
                skew_hi: float | None = None) -> DriftSchedule:
    """Write-ratio step at fraction ``at`` of the run (a flash crowd of
    writers arrives); optionally the skew concentrates at the same time."""
    step = int(round(at * n_segments))
    specs = []
    for k in range(n_segments):
        crowd = k >= step
        repl = {"write_ratio": write_hi if crowd else write_lo}
        if skew_hi is not None and crowd:
            repl["zipf_s"] = skew_hi
        specs.append(dataclasses.replace(base, **repl))
    return DriftSchedule("flash_crowd", tuple(specs))


DRIFT_KINDS = ("stationary", "hot_migration", "skew_ramp", "flash_crowd")
