"""Analytic steady-state oracle for single-hotspot workloads.

For the pure hotspot-update workload (every transaction = one write to one
row), each protocol's throughput is determined by its per-commit serial
chain on that row — closed forms the engine must match (differential
validation of the tick simulator; tests assert agreement within 15%).

Chains (ticks/commit at saturation, T threads, see costs.py semantics):
  mysql/o1 : grant overhead (lock_base + dd_coeff * queue) + op + commit
             (strict 2PL: successor granted only after commit completes)
  o2       : lock_base + op + commit           (no deadlock detection)
  bamboo   : lock_base + dd_coeff * queue + op (early release: commit off
             the serial path; commits pipeline)
  group    : grant_cost + op, amortized lock_base per batch; commits
             batch off-path (group commit)
  brook2pl : lock_base + op (no detection on the grant path; per-op
             release retires the hot ticket at its last use, so the
             commit — like bamboo's — pipelines off the serial chain)
  serial(1): lock_base + op + commit (queue length 0)
"""
from __future__ import annotations

from .costs import CostModel, ProtocolParams, protocol_params
from .metrics import TICKS_PER_SEC


def predicted_tps(proto: str, n_threads: int, costs: CostModel,
                  params: ProtocolParams | None = None) -> float:
    p = params or protocol_params(proto)
    c = costs
    commit = c.commit_base + c.sync_lat
    q = max(n_threads - 1, 0)
    if n_threads == 1:
        chain = p.lock_base + c.op_exec + commit
    elif proto in ("mysql", "o1"):
        chain = p.lock_base + p.dd_coeff * q + c.op_exec + commit
    elif proto == "o2":
        chain = p.lock_base + c.op_exec + commit
    elif proto == "bamboo":
        chain = p.lock_base + p.dd_coeff * q + c.op_exec
    elif proto == "group":
        chain = p.grant_cost + c.op_exec + p.lock_base / max(
            p.batch_size, 1)
    elif proto == "brook2pl":
        chain = (p.lock_base + c.op_exec if p.per_op_release
                 else p.lock_base + c.op_exec + commit)
    else:  # pragma: no cover
        raise ValueError(proto)
    return TICKS_PER_SEC / chain
