"""Grouped conflict-update apply — the paper's technique on tensors (§3.3).

Concurrent updates to the same parameter row are the tensor analogue of
hotspot row updates. The three schedules of the paper's Figure 3 map to:

  * 2PL            -> ``scatter_serial``: one scatter per conflicting update
                      (XLA serializes duplicate indices; every update "takes
                      the lock").
  * Bamboo         -> same data movement, earlier visibility: no tensor
                      analogue of *release timing*, so not materialized.
  * group locking  -> ``group_apply``: form conflict groups (stable sort by
                      key = dependency-list order), execute the group's
                      updates serially *inside* the group (a segment
                      reduction over the sorted run — followers need no
                      "lock"), then write once per group (the leader's
                      single acquire/release).

``group_apply`` is the pure-jnp reference; the Pallas TPU kernel lives in
``repro/kernels/grouped_scatter`` and must match it bit-for-bit in f32.

The hybrid path (``hotspot_apply``) applies the paper §4.1/§4.2 policy:
only rows whose in-batch conflict count exceeds the threshold take the
grouped path; cold rows go through the plain scatter (2PL), exactly like
TXSQL reverting to 2PL for non-hotspot rows.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hotspot import batch_counts, DEFAULT_THRESHOLD


def scatter_serial(table: jnp.ndarray, ids: jnp.ndarray,
                   updates: jnp.ndarray) -> jnp.ndarray:
    """The 2PL analogue: per-update scatter-add (duplicates serialize)."""
    return table.at[ids].add(updates.astype(table.dtype), mode="drop")


class Groups(NamedTuple):
    """Conflict-group structure over a batch of updates."""
    order: jnp.ndarray        # (N,) stable-sort permutation = update order
    sorted_ids: jnp.ndarray   # (N,) ids in group order
    is_leader: jnp.ndarray    # (N,) first update of each group
    group_size: jnp.ndarray   # (N,) size of the group at leader positions


def form_groups(ids: jnp.ndarray) -> Groups:
    """Group conflicting updates; stable order = ``hot_update_order``."""
    ids = ids.reshape(-1)
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    is_leader = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    return Groups(order=order, sorted_ids=sorted_ids, is_leader=is_leader,
                  group_size=_run_lengths(is_leader))


def _run_lengths(is_leader: jnp.ndarray) -> jnp.ndarray:
    """Length of each run, placed at the run's leader position (else 0)."""
    n = is_leader.shape[0]
    idx = jnp.arange(n)
    starts = jnp.where(is_leader, idx, 0)
    starts = jax.lax.associative_scan(jnp.maximum, starts)   # run start
    # run end = next leader's position - 1 (or n-1). In reversed space a
    # position k is a run end iff k == 0 or rev[k-1] (the next original
    # position is a leader); scan-max then propagates the nearest end.
    rev = is_leader[::-1]
    mark = jnp.concatenate([jnp.ones((1,), bool), rev[:-1]])
    rstarts = jnp.where(mark, jnp.arange(n), 0)
    rstarts = jax.lax.associative_scan(jnp.maximum, rstarts)
    ends = (n - 1) - rstarts[::-1]
    return jnp.where(is_leader, ends - starts + 1, 0).astype(jnp.int32)


def group_apply(table: jnp.ndarray, ids: jnp.ndarray,
                updates: jnp.ndarray) -> jnp.ndarray:
    """Group-locking analogue: sort -> in-group serial reduce -> one write
    per group. Pure-jnp reference for the Pallas kernel."""
    ids = ids.reshape(-1)
    updates = updates.reshape((ids.shape[0],) + updates.shape[ids.ndim:])
    g = form_groups(ids)
    upd_sorted = updates[g.order].astype(jnp.float32)
    # segment-reduce within groups: followers fold into the leader slot
    seg = jnp.cumsum(g.is_leader.astype(jnp.int32)) - 1
    n_seg = ids.shape[0]  # upper bound on groups
    summed = jax.ops.segment_sum(upd_sorted, seg, num_segments=n_seg)
    leader_rows = jnp.where(g.is_leader, g.sorted_ids, table.shape[0])
    uniq_ids = jax.ops.segment_min(
        leader_rows.astype(jnp.int32),
        jnp.cumsum(g.is_leader.astype(jnp.int32)) - 1, num_segments=n_seg)
    # one scatter per group (the leader's single lock acquire/release)
    return table.at[uniq_ids].add(summed.astype(table.dtype), mode="drop")


def hotspot_apply(table: jnp.ndarray, ids: jnp.ndarray,
                  updates: jnp.ndarray,
                  threshold: int = DEFAULT_THRESHOLD) -> jnp.ndarray:
    """Hybrid TXSQL policy: hot rows take the grouped path, cold rows the
    plain 2PL scatter. Bit-identical result, different schedule."""
    ids = ids.reshape(-1)
    updates = updates.reshape((ids.shape[0],) + updates.shape[ids.ndim:])
    counts = batch_counts(ids, table.shape[0])
    is_hot = counts[ids] > threshold
    sentinel = jnp.int32(table.shape[0])        # dropped by mode="drop"
    hot_ids = jnp.where(is_hot, ids, sentinel)
    cold_ids = jnp.where(is_hot, sentinel, ids)
    out = scatter_serial(table, cold_ids, updates)
    return group_apply(out, hot_ids, updates * is_hot[:, None].astype(
        updates.dtype) if updates.ndim > 1 else updates * is_hot)
