"""Dependency-list order bookkeeping (§4.2-§4.4, host-side).

A minimal, strictly-checked implementation of the paper's dependency list:
a monotone ``hot_update_order`` is assigned per update; commits must happen
in assigned order; rollbacks in reverse order. Used by the checkpoint
journal (ordered step commits / ordered restore) and the serving queue, and
property-tested directly against the paper's Algorithms 2-3 invariants.
"""
from __future__ import annotations


class DependencyError(RuntimeError):
    pass


class DependencyList:
    """Ordered open-update ledger for one hotspot resource."""

    def __init__(self) -> None:
        self._next_order = 0
        self._open: list[int] = []      # orders in update order, uncommitted

    def assign(self) -> int:
        """New update: append to the dependency list (Alg. 1 line 8-9)."""
        order = self._next_order
        self._next_order += 1
        self._open.append(order)
        return order

    @property
    def open_orders(self) -> tuple[int, ...]:
        return tuple(self._open)

    def can_commit(self, order: int) -> bool:
        """Committable iff no preceding open update (§4.3)."""
        return bool(self._open) and self._open[0] == order

    def commit(self, order: int) -> None:
        if not self.can_commit(order):
            raise DependencyError(
                f"commit order violation: {order} is not the head of "
                f"{self._open}")
        self._open.pop(0)

    def can_rollback(self, order: int) -> bool:
        """Rollbackable iff no subsequent open update (§4.4)."""
        return bool(self._open) and self._open[-1] == order

    def rollback(self, order: int) -> None:
        if not self.can_rollback(order):
            raise DependencyError(
                f"rollback order violation: {order} is not the tail of "
                f"{self._open}")
        self._open.pop()

    def rollback_all_from(self, order: int) -> list[int]:
        """Cascade: roll back every open update >= order, reverse order."""
        rolled = []
        while self._open and self._open[-1] >= order:
            rolled.append(self._open.pop())
        if self._open and order in self._open:  # pragma: no cover
            raise DependencyError("cascade left a stale open order")
        return rolled

    def recover(self, persisted_open: list[int]) -> list[int]:
        """Failure recovery (§5.3): rebuild from persisted orders and
        return the rollback sequence (reverse ``hot_update_order``)."""
        self._open = sorted(persisted_open)
        self._next_order = max(self._next_order,
                               (self._open[-1] + 1) if self._open else 0)
        return list(reversed(self._open))

    def bump(self, next_order: int) -> None:
        """Ensure future orders start at least at ``next_order``."""
        self._next_order = max(self._next_order, next_order)
