"""Paper core: TXSQL lock optimizations, faithful (lock/) and adapted."""
from .hotspot import (DEFAULT_THRESHOLD, HotspotState, batch_counts,
                      detect_hot, init_hotspot, update_hotspot)
from .group_apply import (Groups, form_groups, group_apply, hotspot_apply,
                          scatter_serial)
from .dependency import DependencyList, DependencyError

__all__ = [
    "DEFAULT_THRESHOLD", "HotspotState", "batch_counts", "detect_hot",
    "init_hotspot", "update_hotspot",
    "Groups", "form_groups", "group_apply", "hotspot_apply",
    "scatter_serial", "DependencyList", "DependencyError",
]
