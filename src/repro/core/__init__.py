"""Paper core: TXSQL lock optimizations, faithful (lock/) and adapted."""
from .hotspot import (DEFAULT_THRESHOLD, HotspotState, batch_counts,
                      detect_hot, detect_hot_queue, init_hotspot,
                      update_hotspot, update_hotspot_queue)
from .group_apply import (Groups, form_groups, group_apply, hotspot_apply,
                          scatter_serial)
from .dependency import DependencyList, DependencyError

__all__ = [
    "DEFAULT_THRESHOLD", "HotspotState", "batch_counts", "detect_hot",
    "detect_hot_queue", "init_hotspot", "update_hotspot",
    "update_hotspot_queue",
    "Groups", "form_groups", "group_apply", "hotspot_apply",
    "scatter_serial", "DependencyList", "DependencyError",
]
