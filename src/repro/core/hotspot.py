"""Hotspot detection for skewed-update keys (§4.1 adapted).

The paper promotes a row to *hot* when its lock wait queue exceeds a
threshold (rule of thumb: 32) and demotes it when the queue drains. The
training-side analogue: a parameter row (embedding row, expert) is hot when
the number of conflicting updates targeting it in the current batch exceeds
the threshold; an EMA across steps plays the role of the background sweeper
(promotion persists across steps; demotion when traffic drains).

All functions are pure and jit-safe.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

DEFAULT_THRESHOLD = 32  # the paper's rule-of-thumb queue length


def batch_counts(ids: jnp.ndarray, num_keys: int) -> jnp.ndarray:
    """Per-key update counts in this batch ("queue length" per row)."""
    ones = jnp.ones_like(ids.reshape(-1), dtype=jnp.int32)
    return jnp.zeros((num_keys,), jnp.int32).at[ids.reshape(-1)].add(
        ones, mode="drop")


def detect_hot(ids: jnp.ndarray, num_keys: int,
               threshold: int = DEFAULT_THRESHOLD) -> jnp.ndarray:
    """One-shot hotspot mask: key has > threshold conflicting updates."""
    return batch_counts(ids, num_keys) > threshold


class HotspotState(NamedTuple):
    """EMA of per-key contention, carried across steps."""
    ema: jnp.ndarray          # (num_keys,) f32
    hot: jnp.ndarray          # (num_keys,) bool
    step: jnp.ndarray         # () i32


def init_hotspot(num_keys: int) -> HotspotState:
    return HotspotState(
        ema=jnp.zeros((num_keys,), jnp.float32),
        hot=jnp.zeros((num_keys,), bool),
        step=jnp.zeros((), jnp.int32),
    )


def update_hotspot(state: HotspotState, ids: jnp.ndarray,
                   threshold: int = DEFAULT_THRESHOLD,
                   decay: float = 0.9,
                   demote_below: float = 1.0) -> HotspotState:
    """Advance the detector one step (promotion + sweeper demotion)."""
    counts = batch_counts(ids, state.ema.shape[0]).astype(jnp.float32)
    ema = decay * state.ema + (1.0 - decay) * counts
    promote = counts > threshold
    demote = state.hot & (ema < demote_below)
    return HotspotState(
        ema=ema,
        hot=(state.hot | promote) & ~demote,
        step=state.step + 1,
    )
