"""Hotspot detection for skewed-update keys (§4.1 adapted).

The paper promotes a row to *hot* when its lock wait queue exceeds a
threshold (rule of thumb: 32) and demotes it when the queue drains. The
training-side analogue: a parameter row (embedding row, expert) is hot when
the number of conflicting updates targeting it in the current batch exceeds
the threshold; an EMA across steps plays the role of the background sweeper
(promotion persists across steps; demotion when traffic drains).

All functions are pure and jit-safe.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

DEFAULT_THRESHOLD = 32  # the paper's rule-of-thumb queue length


def batch_counts(ids: jnp.ndarray, num_keys: int) -> jnp.ndarray:
    """Per-key update counts in this batch ("queue length" per row)."""
    ones = jnp.ones_like(ids.reshape(-1), dtype=jnp.int32)
    return jnp.zeros((num_keys,), jnp.int32).at[ids.reshape(-1)].add(
        ones, mode="drop")


def detect_hot(ids: jnp.ndarray, num_keys: int,
               threshold: int = DEFAULT_THRESHOLD) -> jnp.ndarray:
    """One-shot hotspot mask: key has > threshold conflicting updates."""
    return batch_counts(ids, num_keys) > threshold


def detect_hot_queue(queue_depth: jnp.ndarray,
                     threshold: int = DEFAULT_THRESHOLD) -> jnp.ndarray:
    """One-shot hotspot mask from OBSERVED per-lock queue depths.

    The same ``> threshold`` promote rule the lock engine applies to its
    derived wait-queue length every iteration (``engine._hotspot_on``),
    applied to a measured depth vector — e.g. the ``CA_QMAX`` lane of the
    engine's per-record contention accumulator (``Globals.ca``), which
    records each row's peak observed queue depth. This is what unifies
    the batch-side detector with the engine's: both are thresholdings of
    a queue-depth observable, differing only in where the observable
    comes from.
    """
    return jnp.asarray(queue_depth) > threshold


class HotspotState(NamedTuple):
    """EMA of per-key contention, carried across steps."""
    ema: jnp.ndarray          # (num_keys,) f32
    hot: jnp.ndarray          # (num_keys,) bool
    step: jnp.ndarray         # () i32


def init_hotspot(num_keys: int) -> HotspotState:
    return HotspotState(
        ema=jnp.zeros((num_keys,), jnp.float32),
        hot=jnp.zeros((num_keys,), bool),
        step=jnp.zeros((), jnp.int32),
    )


def update_hotspot_queue(state: HotspotState, queue_depth: jnp.ndarray,
                         threshold: int = DEFAULT_THRESHOLD,
                         decay: float = 0.9,
                         demote_below: float = 1.0) -> HotspotState:
    """Advance the detector one step on an observed queue-depth vector.

    Promote when the observed depth crosses ``threshold`` (the paper's
    queue-length-32 rule); demote when the depth EMA drains below
    ``demote_below`` (the background sweeper). This is the shared core:
    :func:`update_hotspot` feeds it batch update counts, the engine
    telemetry path feeds it per-segment observed depths.
    """
    counts = jnp.asarray(queue_depth).astype(jnp.float32)
    ema = decay * state.ema + (1.0 - decay) * counts
    promote = counts > threshold
    demote = state.hot & (ema < demote_below)
    return HotspotState(
        ema=ema,
        hot=(state.hot | promote) & ~demote,
        step=state.step + 1,
    )


def update_hotspot(state: HotspotState, ids: jnp.ndarray,
                   threshold: int = DEFAULT_THRESHOLD,
                   decay: float = 0.9,
                   demote_below: float = 1.0) -> HotspotState:
    """Advance the detector one step (promotion + sweeper demotion)."""
    return update_hotspot_queue(
        state, batch_counts(ids, state.ema.shape[0]),
        threshold=threshold, decay=decay, demote_below=demote_below)
