"""Adaptive contention governor (DESIGN.md §7).

Runs the lock engine in resumable time segments and re-decides the
protocol preset between segments from observed telemetry — the control
half of the paper's hotspot-aware switching, extended to non-stationary
(drifting) workloads. Because every protocol flag, cost, and workload
parameter is traced (PR 1), a governed run compiles once per shape
bucket no matter how often it switches.

Quickstart::

    from repro.adaptive import GovernorCell, QueueRulePolicy, run_governed
    from repro.core.lock import WorkloadSpec, skew_ramp
    drift = skew_ramp(WorkloadSpec(kind="zipf", txn_len=4), 12)
    res = run_governed(
        [GovernorCell("adaptive", QueueRulePolicy(), drift, n_threads=64)],
        horizon=240_000, n_segments=12)
"""
from .governor import (GUARD_CAP, GUARD_FLOOR, PRESETS, DEFAULT_ARMS,
                       guard_timeout, preset_params, preset_family,
                       switch_safe, SegmentRecord, Policy, FixedPolicy,
                       QueueRulePolicy, EpsilonGreedyPolicy)
from .runner import GovernorCell, run_governed, preset_timeline

__all__ = [
    "GUARD_CAP", "GUARD_FLOOR", "PRESETS", "DEFAULT_ARMS",
    "guard_timeout", "preset_params", "preset_family",
    "switch_safe", "SegmentRecord", "Policy", "FixedPolicy",
    "QueueRulePolicy", "EpsilonGreedyPolicy",
    "GovernorCell", "run_governed", "preset_timeline",
]
