"""Governed execution: (policy × drift-scenario) cells on the sweep substrate.

A :class:`GovernorCell` pairs a :class:`~repro.adaptive.governor.Policy`
with a :class:`~repro.core.lock.workload.DriftSchedule`. ``run_governed``
executes every cell as a sequence of resumable engine segments
(``engine.run_segment``): before each segment the cell's policy reads the
telemetry history and picks a preset, the drift schedule supplies the
segment's workload, and the engine is re-entered with the new traced
scalars — the whole run compiles **once per shape bucket** no matter how
often protocols or workloads switch (asserted in tests/test_adaptive.py).

Cells sharing a compile key (kind, padded T, L, R) form one bucket. On a
single small host lanes run sequentially through the shared
``_run_seg_dyn`` executable (the measured-cheaper path, DESIGN.md §3.3);
on multi-device hosts the bucket's lanes are stacked and stepped together
under ``jax.vmap`` (``_run_seg_batch``), segment by segment — policies
stay host-side Python between segments either way.

Results come back as a plain :class:`~repro.sweep.runner.SweepResults`
whose ``segments`` field carries the per-segment time series, so the JSON
store (schema ``repro.sweep/v3``), ``summarize``, and the benchmark
harness all work unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Sequence

import jax
import numpy as np

from repro.core.lock import engine as _engine
from repro.core.lock.costs import CostModel
from repro.core.lock.engine import EngineConfig
from repro.core.lock.metrics import extract_globals, extract_segment
from repro.core.lock.workload import DriftSchedule
from repro.sweep.grid import SweepPoint
from repro.sweep.runner import (BucketInfo, SweepResults, MIN_T_BUCKET,
                                _auto_chunk, _pow2ceil, _take,
                                run_packed_segment)

from .governor import Policy, SegmentRecord, preset_params, switch_safe


@dataclasses.dataclass(frozen=True)
class GovernorCell:
    """One governed run: a policy steering one drifting workload."""
    name: str
    policy: Policy
    drift: DriftSchedule
    n_threads: int
    costs: CostModel = CostModel()
    p_abort: float = 0.0
    attrib: bool = False            # per-record contention accumulator

    def label(self) -> str:
        return self.policy.name


def _cell_config(cell: GovernorCell, preset: str, seg: int,
                 horizon: int, n_segments: int | None = None
                 ) -> EngineConfig:
    return EngineConfig(
        protocol=preset_params(preset, horizon=horizon,
                               n_segments=n_segments),
        costs=cell.costs,
        workload=cell.drift.spec(seg), n_threads=cell.n_threads,
        horizon=horizon, p_abort=cell.p_abort, attrib=cell.attrib)


def _seg_compiles() -> int:
    return (_engine._run_seg_dyn._cache_size()
            + _engine._run_seg_batch._cache_size())


def run_governed(cells: Iterable[GovernorCell], *, horizon: int,
                 n_segments: int, chunk_size: int | None = None,
                 verbose: bool = False) -> SweepResults:
    """Run every cell for ``n_segments`` governed segments over ``horizon``.

    Segment boundaries are ``horizon * (k+1) // n_segments``; a busy cell
    pauses at its first event past the boundary, a stalled one exactly at
    it (``engine._make_step``), so a cell whose policy never switches and
    whose drift is stationary is bit-identical to a single-shot
    ``simulate()`` of the same config — segmentation is pause/resume,
    not restart. ``chunk_size`` bounds how many lanes share one vmapped
    program (1 = sequential single-lane executions); the default adapts
    to the hardware like the sweep runner.
    """
    cells = list(cells)
    names = [c.name for c in cells]
    if len(set(names)) != len(names):
        dup = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate governor cell names: {dup[:5]}")
    for c in cells:
        assert c.drift.n_segments >= 1
    chunk_size = chunk_size or _auto_chunk()

    # bucket by compile key, padding threads to the pow2 cap like the sweep
    buckets: dict[tuple, list[int]] = {}
    pads: dict[int, tuple[int, int]] = {}
    for i, c in enumerate(cells):
        w = c.drift.base
        pad_t = _pow2ceil(c.n_threads, MIN_T_BUCKET)
        pads[i] = (pad_t, w.txn_len)
        buckets.setdefault((w.kind, w.n_rows, pad_t, w.txn_len),
                           []).append(i)

    metrics, wall_us, segments = {}, {}, {}
    infos: list[BucketInfo] = []
    compiles0 = _seg_compiles()
    t_start = time.perf_counter()

    for key, idxs in buckets.items():
        kind, n_rows, pad_t, pad_l = key
        bcells = [cells[i] for i in idxs]
        G = len(bcells)
        t_bucket = time.perf_counter()

        for c in bcells:
            c.policy.reset(c.n_threads)
        history: list[list[SegmentRecord]] = [[] for _ in bcells]

        # initial states + host-side Globals snapshots (all-zero counters)
        stat = None
        states, g_prev, preset0 = [], [], []
        for c in bcells:
            p0 = c.policy.decide(0, [])
            preset0.append(p0)
            st, dp0 = _engine.split_config(
                _cell_config(c, p0, 0, horizon, n_segments),
                pad_threads=pad_t, pad_len=pad_l)
            assert stat is None or st == stat
            stat = st
            s0 = _engine.init_state_dyn(st, dp0)
            states.append(s0)
            g_prev.append(jax.device_get(s0.g))

        # lane groups: at most chunk_size cells share one vmapped program
        # (groups of 1 run through the single-lane executable) — the
        # pow2-width packing lives in the shared packed-segment substrate
        # (sweep.runner.run_packed_segment); passing each group's packed
        # state back keeps the stack device-resident across segments, so
        # a segment costs two small host transfers per group, never
        # per-lane gathers or re-stacks of the big thread/row arrays
        groups = [list(range(lo, min(lo + chunk_size, G)))
                  for lo in range(0, G, max(chunk_size, 1))]
        gpacked: list = [None] * len(groups)

        # Mid-run safety for resolver-free presets (pure brook2pl /
        # brook_hold: no detection walk, no wait timeout — DESIGN §9.2).
        # Such a preset is deadlock-free only while EVERY in-flight
        # transaction follows its current chop order, which holds iff
        # (a) every preceding segment ran an ordered_acquire preset
        # (a single unordered segment can leave cycle-capable holders
        # that outlive many boundaries — a one-segment brook_guard hop
        # does NOT launder them, its timeout may not have fired yet) and
        # (b) the chop rank table has been stable since segment 0
        # (drift that rotates acq_rank, e.g. hot_migration, makes new
        # txns disagree with in-flight ones about the order — measured:
        # a fixed brook_hold cell under hot_migration flatlines to zero
        # commits with no resolver). Violations fail loudly here.
        all_ordered = [True] * G
        rank_stable = [True] * G
        prev_rank: list = [None] * G

        for k in range(n_segments):
            until = horizon * (k + 1) // n_segments
            presets = ([c.policy.decide(k, h)
                        for c, h in zip(bcells, history)]
                       if k else preset0)
            dps = [_engine.split_config(
                _cell_config(c, p, k, horizon, n_segments),
                pad_threads=pad_t, pad_len=pad_l)[1]
                for c, p in zip(bcells, presets)]
            ranks = [np.asarray(dp.wl.acq_rank) for dp in dps]
            for j, (c, p) in enumerate(zip(bcells, presets)):
                if k:
                    rank_stable[j] &= np.array_equal(prev_rank[j],
                                                     ranks[j])
                if k and not switch_safe(p):
                    if not all_ordered[j]:
                        raise ValueError(
                            f"cell {c.name!r}: policy {c.policy.name!r} "
                            f"runs resolver-free preset {p!r} at segment "
                            f"{k} after an unordered-preset segment; "
                            "inherited out-of-order locks can cycle "
                            "unresolvably — use 'brook_guard' instead "
                            "(DESIGN.md §9.2)")
                    if not rank_stable[j]:
                        raise ValueError(
                            f"cell {c.name!r}: drift "
                            f"{c.drift.name!r} rotated the chop rank "
                            f"table by segment {k} while resolver-free "
                            f"preset {p!r} is active; in-flight and new "
                            "transactions would disagree about the lock "
                            "order — use 'brook_guard' under rank-"
                            "rotating drift (DESIGN.md §9.2)")
                all_ordered[j] &= bool(preset_params(p).ordered_acquire)
            prev_rank = ranks
            outs: list = [None] * G
            for gi, grp in enumerate(groups):
                gpacked[gi], snaps, w = run_packed_segment(
                    stat, [dps[j] for j in grp],
                    [states[j] for j in grp], [until] * len(grp),
                    packed=gpacked[gi])
                g_host = jax.device_get(gpacked[gi].g)
                snap_host = jax.device_get(snaps)
                if w == 1:
                    outs[grp[0]] = (g_host, snap_host)
                else:
                    for lane, j in enumerate(grp):
                        outs[j] = (_take(g_host, lane),
                                   _take(snap_host, lane))
            for j, (c, p) in enumerate(zip(bcells, presets)):
                g_now, snap = outs[j]
                r = extract_segment(p, c.n_threads, g_prev[j], g_now)
                history[j].append(SegmentRecord(
                    index=k, t0=int(g_prev[j].now), t1=int(g_now.now),
                    preset=p, metrics=r, max_qlen=int(snap.max_qlen),
                    n_hot=int(snap.n_hot), n_live=int(snap.n_live),
                    n_waiting=int(snap.n_waiting),
                    wait_hist=tuple(int(v) for v in snap.wait_hist),
                    occ_hist=tuple(int(v) for v in snap.occ_hist)))
                g_prev[j] = g_now

        wall_b = time.perf_counter() - t_bucket
        for j, c in enumerate(bcells):
            metrics[c.name] = extract_globals(c.label(), c.n_threads,
                                              g_prev[j])
            wall_us[c.name] = wall_b * 1e6 / G
            segments[c.name] = [r.as_json() for r in history[j]]
        infos.append(BucketInfo(
            family="governed", kind=kind, n_rows=n_rows, pad_threads=pad_t,
            pad_len=pad_l, n_points=G, n_chunks=len(groups), wall_s=wall_b))
        if verbose:
            print(f"# governed bucket {kind}/R{n_rows}: {G} cell(s), "
                  f"T<={pad_t}, {n_segments} segment(s), {wall_b:.1f}s")

    points = [SweepPoint(
        protocol=c.label(), workload=c.drift.base, n_threads=c.n_threads,
        horizon=horizon, p_abort=c.p_abort, costs=c.costs,
        name=c.name, tag=c.drift.name) for c in cells]
    return SweepResults(
        points=points, metrics=metrics, wall_us=wall_us, buckets=infos,
        n_compiles=_seg_compiles() - compiles0,
        wall_s=time.perf_counter() - t_start, segments=segments)


def preset_timeline(res: SweepResults, name: str) -> list[str]:
    """The per-segment preset sequence a cell's policy chose."""
    return [seg["preset"] for seg in res.segments[name]]
