"""Governor policies: re-decide the lock protocol between run segments.

The engine exposes resumable segments (``engine.run_segment``) whose
boundaries deliver telemetry — counter deltas (throughput, aborts, waits;
``metrics.extract_segment``) plus instantaneous contention state
(``engine.SegSnapshot``). A *policy* maps that history to the **preset**
(a named ``ProtocolParams`` configuration) to run for the next segment.
Because every protocol flag/cost is traced (``DynParams``), acting on a
decision is free: the segmented executable is simply re-entered with new
scalars — no recompile (DESIGN.md §7).

Three policy families (the issue's preset-table governor):

* :class:`FixedPolicy` — a pinned preset; the baselines in every figure.
* :class:`QueueRulePolicy` — the paper's hotspot rule (§4.1) lifted to the
  governor: a deep single-row queue means group locking wins; a full-stall
  wait pattern (every thread blocked, CPU idle, no aborts) is the
  detection-free deadlock signature, so fall back to strict 2PL; a calm
  system takes the cheapest lock path. Thresholds are in protocol-agnostic
  units (fractions of the active thread count).
* :class:`EpsilonGreedyPolicy` — model-free search over the preset table:
  bootstrap every arm once, exploit the best recent estimate, re-explore
  when the incumbent's throughput collapses; estimates decay with age, and
  a collapse taints same-*family* arms (protocols sharing the lock-grant
  machinery stall together — o2 and group are indistinguishable absent hot
  rows), so the governor does not waste a probe confirming a correlated
  collapse.
"""
from __future__ import annotations

import dataclasses

from repro.core.lock.costs import ProtocolParams, protocol_params
from repro.core.lock.metrics import SimResult

# ---------------------------------------------------------------------------
# preset table
# ---------------------------------------------------------------------------
# name -> (base protocol, overrides, family). Families group presets whose
# grant machinery behaves identically when no row is promoted hot: a
# detection-free stall observed on one member is evidence about the others.

PRESETS: dict[str, tuple[str, dict, str]] = {
    "mysql": ("mysql", {}, "detect"),
    "o1": ("o1", {}, "detect"),
    "o2": ("o2", {}, "queue"),
    "group": ("group", {}, "queue"),
    "bamboo": ("bamboo", {}, "early"),
    # knob variants (hill-climbing targets): eager promotion / batch sizing
    "group_eager": ("group", {"hot_threshold": 8}, "queue"),
    "group_batch4": ("group", {"batch_size": 4}, "queue"),
    "group_batch32": ("group", {"batch_size": 32}, "queue"),
    # Brook-2PL (chop-ordered, deadlock-free; family "brook"). The
    # deadlock-freedom claim covers transactions GENERATED under the
    # chop order — a FixedPolicy("brook2pl") run from segment 0 never
    # stalls, aborts, or pays detection. Switching INTO brook2pl
    # mid-run is different: in-flight transactions generated under the
    # previous preset's (un)ordering can already hold locks in a cycle,
    # and pure brook has NO resolver (no detection walk, no timeouts) —
    # an inherited cycle would stall the run until the horizon, so
    # ``run_governed`` REJECTS such switches loudly (see
    # :func:`switch_safe`). Policies that switch protocols use
    # `brook_guard` (wait timeout re-armed as the residual resolver;
    # zero false timeouts on brook-generated waits and recovery from an
    # inherited cycle are both asserted in tests/test_adaptive.py).
    # `brook_hold` keeps ordered acquisition but holds to commit
    # (strict 2PL without deadlocks, for heavy injected-abort mixes
    # where early readers are wasted work).
    # The guard timeout here is the context-free fallback (10 ms): an
    # order of magnitude above any legitimate chop-ordered wait at
    # governed thread counts (T<=128: ~10k ticks of queued holders), so
    # brook traffic never falsely times out. Runners that know their
    # segmentation pass horizon/n_segments to ``preset_params`` and get
    # :func:`guard_timeout` instead — half a segment, clamped — so a
    # cycle inherited at the LAST segment boundary still resolves before
    # the horizon (the fixed 100k guard could outlive a late switch-in's
    # remaining run; regression-tested in tests/test_adaptive.py).
    "brook2pl": ("brook2pl", {}, "brook"),
    "brook_hold": ("brook2pl", {"per_op_release": False}, "brook"),
    "brook_guard": ("brook2pl", {"wait_timeout": 100_000,
                                 "commit_wait_timeout": 100_000}, "brook"),
}

DEFAULT_ARMS = ("o2", "group", "mysql")


# guard-timeout derivation bounds (ticks). The floor keeps the guard an
# order of magnitude above legitimate chop-ordered waits at governed
# thread counts (no false timeouts on brook-generated traffic, asserted
# in tests/test_adaptive.py); the cap keeps it at the old fixed value —
# segmenting more coarsely than 200k-tick segments gains nothing because
# inherited-cycle stalls longer than that were already resolvable.
GUARD_FLOOR = 20_000
GUARD_CAP = 100_000


def guard_timeout(horizon: int, n_segments: int) -> int:
    """Derived residual-resolver timeout: half a governed segment,
    clamped to [GUARD_FLOOR, GUARD_CAP]. Half, so a cycle inherited at a
    segment boundary — the only place switches happen — resolves with
    segment time to spare even when the switch lands on the LAST
    boundary."""
    seg = int(horizon) // max(int(n_segments), 1)
    return max(GUARD_FLOOR, min(GUARD_CAP, seg // 2))


def preset_params(name: str, *, horizon: int | None = None,
                  n_segments: int | None = None) -> ProtocolParams:
    """Resolve a preset. When the caller supplies its segmentation
    (``horizon`` + ``n_segments``), presets that re-arm the wait timeout
    as their residual deadlock resolver (an explicit positive
    ``wait_timeout`` override — brook_guard) get :func:`guard_timeout`
    instead of the fixed fallback. Presets whose timeouts are protocol
    semantics (mysql's 500k default, brook2pl's hard 0) are untouched."""
    proto, over, _ = PRESETS[name]
    if (horizon is not None and n_segments is not None
            and over.get("wait_timeout", 0) > 0):
        g = guard_timeout(horizon, n_segments)
        over = dict(over, wait_timeout=g, commit_wait_timeout=g)
    return protocol_params(proto, **over)


def preset_family(name: str) -> str:
    return PRESETS[name][2]


def switch_safe(name: str) -> bool:
    """Can a governed run adopt this preset MID-RUN (segment k > 0)?

    A preset with no dynamic deadlock resolver (no detection walk, no
    wait timeout) relies on every in-flight transaction having been
    generated under its chop order — true from segment 0 or when the
    previous preset already ordered acquisitions, false after a switch
    from an unordered preset, where inherited out-of-order holders can
    cycle unresolvably (DESIGN.md §9.2). Derived from the params, not a
    hand-list, so knob variants inherit the right answer.
    """
    p = preset_params(name)
    return bool(p.has_detection or p.wait_timeout > 0)


# ---------------------------------------------------------------------------
# segment records (what a policy sees)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SegmentRecord:
    """One governed segment: window metrics + end-of-segment state."""
    index: int
    t0: int                 # segment entry sim-time (ticks)
    t1: int                 # segment exit sim-time
    preset: str             # preset that ran this segment
    metrics: SimResult      # counter deltas over [t0, t1]
    max_qlen: int           # longest row wait queue at t1
    n_hot: int              # promoted-hot rows at t1
    n_live: int             # live tickets at t1
    n_waiting: int          # threads in a wait phase at t1
    # distribution observables at t1 (obs layer, engine.SegSnapshot):
    # log2-bucket histograms of row wait-queue depth (all rows) and live-
    # ticket occupancy (hot rows only). Defaulted empty so pre-PR7 record
    # construction sites / pickles keep working.
    wait_hist: tuple = ()
    occ_hist: tuple = ()

    def as_json(self) -> dict:
        """Compact time-series entry for the results store (v3 schema)."""
        m = self.metrics
        return {
            "index": self.index, "t0": self.t0, "t1": self.t1,
            "preset": self.preset, "tps": m.tps, "commits": m.commits,
            "aborts": m.user_aborts + m.forced_aborts,
            "abort_rate": m.abort_rate, "lock_wait_frac": m.lock_wait_frac,
            "cpu_util": m.cpu_util, "max_qlen": self.max_qlen,
            "n_hot": self.n_hot, "n_live": self.n_live,
            "n_waiting": self.n_waiting,
            # v3 additions: per-window TickBreakdown (ticks per bin,
            # branches summed; conserves to pad_T * (t1 - t0)) and the
            # end-of-segment distribution histograms
            "breakdown": dict(m.breakdown),
            "wait_hist": list(self.wait_hist),
            "occ_hist": list(self.occ_hist),
            # v4 addition: per-window top-K contended records from the
            # contention accumulator delta (empty when EngineConfig.attrib
            # is off); wait_ticks summed over ALL rows equals
            # breakdown["lock_wait"] exactly (conservation, DESIGN.md §14)
            "hotspots": [dict(h) for h in getattr(m, "hotspots", [])],
        }


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

class Policy:
    """Decides the preset for segment ``k`` from the segment history.

    Stateful: one instance governs one cell. ``reset`` is called by the
    runner before segment 0 with the cell's active thread count.
    """
    name = "policy"

    def reset(self, n_threads: int) -> None:
        self.n_threads = n_threads

    def decide(self, k: int, history: list[SegmentRecord]) -> str:
        raise NotImplementedError


class FixedPolicy(Policy):
    """Always the same preset — the single-protocol baselines."""

    def __init__(self, preset: str):
        assert preset in PRESETS, preset
        self.preset = preset
        self.name = f"fixed:{preset}"

    def decide(self, k, history):
        return self.preset


class QueueRulePolicy(Policy):
    """The paper's queue-threshold rule as a governor (§4.1, extended).

    Reads only the last segment's telemetry:

    1. hotspot — ``max_qlen >= promote_frac * T`` AND the waiters are
       *concentrated* on that queue (``max_qlen >= conc_frac *
       n_waiting``): group locking's territory. Concentration is what
       separates a hot row (migration probe: qlen 120 of 122 waiting)
       from a deadlock pile-up whose queues are long but dispersed
       (flash-crowd probe: qlen 25 of 64 waiting).
    2. stall — ``n_waiting >= stall_frac * T`` without case 1: most
       threads blocked across dispersed queues is the detection-free
       deadlock-stall signature (measured: a forming stall shows ~0.65T
       waiting one segment before the full absorbing stall): run the
       detection preset. Detection protocols under heavy contention also
       sit here, which keeps them put — this branch only *moves to*
       detection.
    3. calm (``lock_wait_frac <= calm_wait`` and ``n_waiting`` tiny) —
       no contention to manage: cheapest lock path.
    4. otherwise keep the incumbent (hysteresis; ambiguous mid states —
       e.g. 2PL quietly absorbing a deadlock-prone mix — stay put).
    """

    def __init__(self, *, hot: str = "group", detect: str = "mysql",
                 calm: str = "o2", promote_frac: float = 0.5,
                 conc_frac: float = 0.75, stall_frac: float = 0.6,
                 calm_wait: float = 0.05, calm_nwait_frac: float = 0.06,
                 name: str = "rule"):
        for p in (hot, detect, calm):
            assert p in PRESETS, p
        self.hot, self.detect, self.calm = hot, detect, calm
        self.promote_frac = promote_frac
        self.conc_frac = conc_frac
        self.stall_frac = stall_frac
        self.calm_wait = calm_wait
        self.calm_nwait_frac = calm_nwait_frac
        self.name = name

    def decide(self, k, history):
        if not history:
            return self.calm
        r = history[-1]
        T = self.n_threads
        if (r.max_qlen >= self.promote_frac * T
                and r.n_waiting > 0
                and r.max_qlen >= self.conc_frac * r.n_waiting):
            return self.hot
        if r.n_waiting >= self.stall_frac * T:
            return self.detect
        if (r.metrics.lock_wait_frac <= self.calm_wait
                and r.n_waiting <= max(2.0, self.calm_nwait_frac * T)):
            return self.calm
        return r.preset


class EpsilonGreedyPolicy(Policy):
    """Bootstrap-explore / exploit / drop-triggered re-explore over arms.

    Estimates are each arm's most recent observed segment throughput,
    decayed by ``decay`` per segment of age (stale knowledge fades; the
    incumbent, refreshed every segment, is compared at face value). When
    the incumbent's throughput falls below ``drop_frac`` times its recent
    best (a window of its own in-regime observations), the regime has
    shifted: all estimates are invalidated and re-probed best-first —
    except same-family arms, which inherit the collapsed observation
    (a detection-free stall on one queue-family member indicts them all).
    ``explore_every > 0`` adds scheduled re-probes of the stalest arm
    (the classic epsilon term; off by default — decayed exploitation plus
    drop-triggered re-exploration covers drifting regimes deterministically).
    """

    def __init__(self, arms=DEFAULT_ARMS, *, decay: float = 0.85,
                 drop_frac: float = 0.5, window: int = 3,
                 explore_every: int = 0, name: str = "greedy"):
        assert len(arms) >= 1
        for a in arms:
            assert a in PRESETS, a
        self.arms = tuple(arms)
        self.decay = decay
        self.drop_frac = drop_frac
        self.window = window
        self.explore_every = explore_every
        self.name = name

    def reset(self, n_threads):
        super().reset(n_threads)
        self.est: dict[str, float] = {}     # arm -> last observed tps
        self.seen: dict[str, int] = {}      # arm -> segment of observation
        self.valid: dict[str, bool] = {}    # arm -> observed this regime?
        self.recent: dict[str, list] = {a: [] for a in self.arms}

    def _ingest(self, r: SegmentRecord):
        arm, tps = r.preset, r.metrics.tps
        if arm not in self.arms:
            return
        win = self.recent[arm]
        wmax = max(win) if win else 0.0
        if self.valid.get(arm) and wmax > 0 and tps < self.drop_frac * wmax:
            # regime shift under the incumbent: invalidate everything,
            # propagating the collapse to the incumbent's family.
            fam = preset_family(arm)
            for a in self.arms:
                self.valid[a] = False
                if a != arm and preset_family(a) == fam:
                    self.est[a] = tps
                    self.seen[a] = r.index
                    self.valid[a] = True
                    self.recent[a] = [tps]
            self.recent[arm] = []
        self.est[arm] = tps
        self.seen[arm] = r.index
        self.valid[arm] = True
        self.recent[arm] = (self.recent[arm] + [tps])[-self.window:]

    def decide(self, k, history):
        if history:
            self._ingest(history[-1])
        # bootstrap / re-probe: unobserved or invalidated arms, best-first
        pending = [a for a in self.arms if a not in self.est]
        if pending:
            return pending[0]
        stale = [a for a in self.arms if not self.valid.get(a)]
        if stale:
            return max(stale, key=lambda a: self.est[a])
        if self.explore_every and k > 0 and k % self.explore_every == 0:
            return min(self.arms, key=lambda a: self.seen[a])
        return max(self.arms,
                   key=lambda a: self.est[a]
                   * self.decay ** max(0, k - self.seen[a] - 1))
