"""Gradient compression: int8 ring all-reduce with error feedback (a
distributed-optimization trick for the slow multi-pod axis).

Runs inside ``shard_map`` over a data-parallel mesh axis. Each step:
  1. add the error-feedback residual to the local gradient,
  2. quantize to int8 with per-block f32 scales (4x less wire than f32,
     2x less than bf16),
  3. ring all-reduce via ``lax.ppermute`` — each hop moves int8 + scales,
  4. keep the quantization error as next step's residual (so the bias is
     corrected over steps; standard EF-SGD argument).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

BLOCK = 2048  # quantization block (per-block scale)


def _axis_size(axis_name) -> int:
    """lax.axis_size, with a fallback for jax<=0.4.37 (axis env lookup —
    core.axis_frame already resolves to the size there)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    from jax import core
    return core.axis_frame(axis_name)


# pvary marks values as device-varying for shard_map's replication checks;
# older jax has no such notion, so identity is the correct fallback.
_pvary = getattr(lax, "pvary", lambda x, names: x)


def _blocked(x: jnp.ndarray):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, BLOCK), pad


def quantize(x: jnp.ndarray):
    """x: (..., B). Returns int8 values + f32 per-row scales."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def quantized_psum(x: jnp.ndarray, axis_name: str, residual=None):
    """Quantized ring all-reduce over `axis_name` (call inside shard_map).

    Returns (sum over the axis, new error-feedback residual). The sum is
    of *quantized* contributions; each device's quantization error stays
    local in `residual` and is re-injected next call.
    """
    n = _axis_size(axis_name)
    xf = _pvary(x.astype(jnp.float32), (axis_name,))
    if residual is not None:
        xf = xf + _pvary(residual, (axis_name,))
    blocks, pad = _blocked(xf)
    q, s = quantize(blocks)
    err = (blocks - dequantize(q, s)).reshape(-1)
    err = (err[:-pad] if pad else err).reshape(x.shape)

    perm = [(i, (i + 1) % n) for i in range(n)]

    def hop(_, carry):
        acc, q, s = carry
        q = lax.ppermute(q, axis_name, perm)
        s = lax.ppermute(s, axis_name, perm)
        return acc + dequantize(q, s), q, s

    acc = dequantize(q, s)
    acc, _, _ = lax.fori_loop(0, n - 1, hop, (acc, q, s))
    out = acc.reshape(-1)
    out = (out[:-pad] if pad else out).reshape(x.shape)
    return out, err
