"""Hotspot-grouped embedding gradient (the paper's technique on the
training hot path).

Embedding backward is a scatter-add of per-token cotangents into vocab
rows with Zipf-distributed indices — the literal hotspot-update workload.
``grouped_embed`` swaps XLA's serialized duplicate-index scatter for the
conflict-group schedule (stable sort -> in-group segment reduction -> one
write per distinct row) via a custom VJP; numerically identical (f32
accumulation), different schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.group_apply import group_apply


@jax.custom_vjp
def grouped_embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return table[tokens]


def _fwd(table, tokens):
    return table[tokens], (tokens, table.shape, table.dtype)


def _bwd(res, ct):
    tokens, tshape, tdtype = res
    ids = tokens.reshape(-1)
    upd = ct.reshape(-1, tshape[-1])
    zero = jnp.zeros(tshape, jnp.float32)
    # conflict-group apply: sort + segment-reduce + one write per group
    dtable = group_apply(zero, ids, upd.astype(jnp.float32))
    return dtable.astype(tdtype), None


grouped_embed.defvjp(_fwd, _bwd)


def serial_embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Baseline path: XLA's native gather/scatter-add VJP (2PL analogue)."""
    return table[tokens]
