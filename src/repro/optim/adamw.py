"""AdamW with global-norm clipping and cosine schedule (self-contained)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # moment storage: 32 (f32), 16 (bf16), 8 (blockwise-int8 a la bnb).
    # 8-bit states are what makes arctic-480b training fit a 256-chip pod
    # (12 -> 6 bytes/param of optimizer+master state).
    state_bits: int = 32


def _q8(x: jnp.ndarray):
    """Shape-preserving int8 quantization: q mirrors the parameter shape
    (so it inherits the parameter's sharding with NO resharding); one f32
    scale per last-axis row."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _dq8(packed, shape):
    return packed["q"].astype(jnp.float32) * packed["s"]


def _pack(x: jnp.ndarray, bits: int):
    if bits == 32:
        return x
    if bits == 16:
        return x.astype(jnp.bfloat16)
    return _q8(x)


def _unpack(x, shape, bits: int):
    if bits == 32:
        return x
    if bits == 16:
        return x.astype(jnp.float32)
    return _dq8(x, shape)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) \
        * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, state_bits: int = 32) -> AdamWState:
    def z(p):
        return _pack(jnp.zeros(p.shape, jnp.float32), state_bits)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(z, params),
                      v=jax.tree.map(z, params))


def abstract_state(param_structs, state_bits: int = 32) -> AdamWState:
    """ShapeDtypeStruct optimizer state (dry-run input)."""
    def z(p):
        if state_bits == 32:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        if state_bits == 16:
            return jax.ShapeDtypeStruct(p.shape, jnp.bfloat16)
        return {"q": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                "s": jax.ShapeDtypeStruct(p.shape[:-1] + (1,),
                                          jnp.float32)}
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree.map(z, param_structs),
                      v=jax.tree.map(z, param_structs))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * _unpack(m, p.shape, cfg.state_bits) + (1 - cfg.b1) * g
        v = cfg.b2 * _unpack(v, p.shape, cfg.state_bits) \
            + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, _pack(m, cfg.state_bits), _pack(v, cfg.state_bits)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gnorm, "lr": lr}
