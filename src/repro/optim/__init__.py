from .adamw import AdamWConfig, AdamWState, init, apply, schedule, global_norm
from .hotspot_update import grouped_embed, serial_embed
from .compression import quantized_psum, quantize, dequantize

__all__ = ["AdamWConfig", "AdamWState", "init", "apply", "schedule",
           "global_norm", "grouped_embed", "serial_embed",
           "quantized_psum", "quantize", "dequantize"]
