"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    layout=(((("global", "moe+dense"),), 35),),
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,              # dense residual MLP width
    vocab=32000,
    head_dim=128,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    rope_theta=1e4,
    vocab_pad_to=256,
    source="hf:Snowflake/snowflake-arctic-base",
)

SMOKE = dataclasses.replace(
    CONFIG, name="arctic-480b-smoke",
    layout=(((("global", "moe+dense"),), 2),),
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    n_experts=8, top_k=2, moe_d_ff=64, remat=False)
