"""Model configuration system: architectures, layer layouts, input shapes.

A ``ModelConfig`` fully describes one architecture. Layers are organized in
**layer groups** ``(unit, repeats)``: a unit is a short tuple of layer kinds
(e.g. five sliding-window attention layers followed by one global layer for
gemma3) and the group is compiled as one ``lax.scan`` over ``repeats`` with
parameters stacked on a leading axis — this keeps compile time bounded for
62-layer models while expressing heterogeneous patterns exactly.

A ``LayerKind`` is ``(mixer, mlp)``:
  mixer: "global" | "local" | "mla" | "rglru" | "ssd"
  mlp:   "dense" | "moe" | "moe+dense" | "none"
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

LayerKind = Tuple[str, str]
LayerGroup = Tuple[Tuple[LayerKind, ...], int]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    layout: Tuple[LayerGroup, ...]
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # attention
    window: int = 4096          # sliding-window size for "local"
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mrope: bool = False         # qwen2-vl 3-section M-RoPE
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # data-shard-local dispatch: capacity grids are per data shard (set to
    # the mesh's data-parallel size in distributed runs; EP all-to-alls
    # then move only shard-local capacity, not global)
    moe_data_shards: int = 1
    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # RG-LRU (recurrentgemma)
    lru_width: int = 0
    conv_width: int = 4
    # SSD (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # embedding / head
    n_codebooks: int = 0        # musicgen: output heads over codebooks
    embed_inputs: bool = True   # False: frontend stub feeds embeddings
    vocab_pad_to: int = 1       # pad vocab to a multiple (sharding)
    norm_eps: float = 1e-6
    # training
    remat: bool = True
    zloss: float = 1e-4
    act_dtype: str = "bfloat16"   # activation/cache dtype
    loss_chunk: int = 0           # sequence-chunked CE (0 = off); keeps
                                  # logits from ever materializing fully
    attn_chunk: int = 0           # query-block-chunked attention (0 = off);
                                  # scores exist one (blk x S) slab at a
                                  # time (flash-style memory, XLA-level)
    unroll_layers: bool = False   # python-loop layer groups (cost probes)
    kv_dtype: str = "bfloat16"    # KV-cache storage dtype; "float8_e4m3fn"
                                  # halves decode HBM traffic (hillclimb)
    # citation / provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return sum(len(unit) * reps for unit, reps in self.layout)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def kinds(self) -> set:
        return {k for unit, _ in self.layout for k in unit}

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        n = 0
        if self.embed_inputs:
            n += self.padded_vocab * d
        heads_out = self.n_codebooks or 1
        n += heads_out * self.padded_vocab * d          # lm head(s)
        for unit, reps in self.layout:
            for mixer, mlp in unit:
                if mixer in ("global", "local"):
                    n += reps * d * hd * (self.n_heads * 2
                                          + self.n_kv_heads * 2)
                elif mixer == "mla":
                    qk = self.qk_nope_dim + self.qk_rope_dim
                    n += reps * (d * self.n_heads * qk
                                 + d * (self.kv_lora_rank + self.qk_rope_dim)
                                 + self.kv_lora_rank * self.n_heads
                                 * (self.qk_nope_dim + self.v_head_dim)
                                 + self.n_heads * self.v_head_dim * d)
                elif mixer == "rglru":
                    w = self.lru_width
                    n += reps * (2 * d * w + w * d + 3 * w
                                 + self.conv_width * w)
                elif mixer == "ssd":
                    di, ns, hh = self.d_inner, self.ssm_state, self.ssm_heads
                    n += reps * (d * (2 * di + 2 * ns + hh)
                                 + di * d + self.conv_width * (di + 2 * ns))
                if mlp == "dense":
                    n += reps * 3 * d * self.d_ff
                elif mlp in ("moe", "moe+dense"):
                    n += reps * (self.n_experts * 3 * d * self.moe_d_ff
                                 + self.n_shared_experts * 3 * d
                                 * self.moe_d_ff + d * self.n_experts)
                    if mlp == "moe+dense":
                        n += reps * 3 * d * self.d_ff
                n += reps * 2 * d                        # norms
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(reps * sum(1 for _, m in unit if "moe" in m)
                         for unit, reps in self.layout)
        inactive = moe_layers * (self.n_experts - self.top_k) * 3 \
            * self.d_model * self.moe_d_ff
        return full - inactive


# ---------------------------------------------------------------------------
# assigned input shapes (LM-family: seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs with a sub-quadratic decode path run long_500k; pure full-attention
# archs skip it (documented in DESIGN.md §4).
SUBQUADRATIC = {"gemma3-12b", "recurrentgemma-2b", "mamba2-1.3b"}


def shape_grid(arch_name: str):
    """The assigned (shape) cells for one architecture."""
    for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if s == "long_500k" and arch_name not in SUBQUADRATIC:
            continue
        yield SHAPES[s]
