"""mamba2-1.3b [ssm] — SSD (state-space duality), attn-free
[arXiv:2405.21060; unverified]. Vocab padded to 50432 for sharding (the
model's logical vocab 50280 is kept for losses/logits masking)."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    layout=(((("ssd", "none"),), 48),),
    d_model=2048,
    n_heads=1,                # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    vocab_pad_to=256,         # 50280 -> 50432 (divisible by 256)
    source="arXiv:2405.21060",
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-1.3b-smoke",
    layout=(((("ssd", "none"),), 2),),
    d_model=64, vocab=256, ssm_state=16, ssm_head_dim=16, ssm_expand=2,
    ssm_chunk=8, remat=False)
