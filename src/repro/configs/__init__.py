"""Architecture registry + per-shape input specs (ShapeDtypeStructs).

``input_specs(cfg, shape)`` returns abstract inputs for the step function a
shape lowers (train_step / prefill_step / serve_step), following the
assignment: [audio]/[vlm] archs get precomputed frame/patch embeddings
(frontend stubs); decode shapes get a KV/state cache of ``seq_len``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeSpec, SHAPES, SUBQUADRATIC, shape_grid
from . import (deepseek_coder_33b, qwen2_0_5b, gemma3_12b, command_r_35b,
               arctic_480b, deepseek_v2_lite_16b, recurrentgemma_2b,
               musicgen_medium, qwen2_vl_2b, mamba2_1_3b)

_MODULES = {
    "deepseek-coder-33b": deepseek_coder_33b,
    "qwen2-0.5b": qwen2_0_5b,
    "gemma3-12b": gemma3_12b,
    "command-r-35b": command_r_35b,
    "arctic-480b": arctic_480b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "musicgen-medium": musicgen_medium,
    "qwen2-vl-2b": qwen2_vl_2b,
    "mamba2-1.3b": mamba2_1_3b,
}

ARCHS = tuple(_MODULES.keys())


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return _MODULES[name].SMOKE if smoke else _MODULES[name].CONFIG


def input_specs(cfg: ModelConfig, shape: ShapeSpec, per_host_batch=None):
    """Abstract inputs (no allocation) for the step lowered by `shape`."""
    B = per_host_batch or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    def emb(b, s):
        return jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16)

    if shape.step == "train":
        batch = {}
        if cfg.embed_inputs:
            batch["tokens"] = tok(B, S)
        else:
            batch["embeds"] = emb(B, S)
        if cfg.n_codebooks:
            batch["labels"] = jax.ShapeDtypeStruct((B, S, cfg.n_codebooks),
                                                   i32)
        else:
            batch["labels"] = tok(B, S)
        if cfg.mrope:
            batch["positions3"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return {"batch": batch}

    if shape.step == "prefill":
        d = {}
        if cfg.embed_inputs:
            d["tokens"] = tok(B, S)
        else:
            d["embeds"] = emb(B, S)
        if cfg.mrope:
            d["positions3"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return d

    # decode: one new token against a seq_len cache
    from repro.models.transformer import lm_cache_shapes
    d = {"caches": lm_cache_shapes(cfg, B, S, jnp.dtype(cfg.kv_dtype)),
         "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.embed_inputs:
        d["tokens"] = tok(B, 1)
    else:
        d["embeds"] = emb(B, 1)
    if cfg.mrope:
        d["positions3"] = jax.ShapeDtypeStruct((3, B, 1), i32)
    return d


__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "SUBQUADRATIC",
           "shape_grid", "ARCHS", "get_config", "input_specs"]
