"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01;
unverified]."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    layout=(((("global", "dense"),), 40),),
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    head_dim=128,
    rope_theta=8e6,
    vocab_pad_to=256,
    source="hf:CohereForAI/c4ai-command-r-v01",
)

SMOKE = dataclasses.replace(
    CONFIG, name="command-r-35b-smoke",
    layout=(((("global", "dense"),), 2),),
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    remat=False)
