"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, pattern
(rec, rec, attn) x 8 + 2 rec = 26 layers [arXiv:2402.19427; hf]."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    layout=(
        (((("rglru", "dense")), ("rglru", "dense"), ("local", "dense")), 8),
        ((("rglru", "dense"),), 2),
    ),
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    window=2048,
    lru_width=2560,
    conv_width=4,
    rope_theta=1e4,
    vocab_pad_to=256,
    source="arXiv:2402.19427",
)

SMOKE = dataclasses.replace(
    CONFIG, name="recurrentgemma-2b-smoke",
    layout=(((("rglru", "dense"), ("local", "dense")), 2),),
    d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=256, head_dim=16,
    window=16, lru_width=64, remat=False)
