"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + routed top-6
[arXiv:2405.04434; hf]. Layer 0 is dense, remaining 26 are MoE."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    layout=(
        ((("mla", "dense"),), 1),
        ((("mla", "moe"),), 26),
    ),
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,             # dense layer-0 FFN
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    rope_theta=1e4,
    vocab_pad_to=256,
    source="arXiv:2405.04434",
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-v2-lite-16b-smoke",
    layout=(((("mla", "dense"),), 1), ((("mla", "moe"),), 1)),
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=32,
    kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
    remat=False)
