"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196; hf]."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    layout=(((("global", "dense"),), 62),),
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    head_dim=128,
    rope_theta=1e5,
    vocab_pad_to=256,
    source="arXiv:2401.14196",
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-coder-33b-smoke",
    layout=(((("global", "dense"),), 2),),
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    remat=False)
