"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. Frontend stub: input_specs() provides precomputed
frame embeddings; the model emits 4 parallel codebook heads."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    layout=(((("global", "dense"),), 48),),
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    head_dim=64,
    n_codebooks=4,
    embed_inputs=False,       # EnCodec frontend stub
    rope_theta=1e4,
    vocab_pad_to=128,
    source="arXiv:2306.05284",
)

SMOKE = dataclasses.replace(
    CONFIG, name="musicgen-medium-smoke",
    layout=(((("global", "dense"),), 2),),
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=64, head_dim=16,
    n_codebooks=2, remat=False)
