"""qwen2-0.5b [dense] — GQA, QKV bias [arXiv:2407.10671; hf]."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    layout=(((("global", "dense"),), 24),),
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1e6,
    vocab_pad_to=256,
    source="arXiv:2407.10671",
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-0.5b-smoke",
    layout=(((("global", "dense"),), 2),),
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    remat=False)
