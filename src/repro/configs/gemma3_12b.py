"""gemma3-12b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
import dataclasses
from .base import ModelConfig

_UNIT = (("local", "dense"),) * 5 + (("global", "dense"),)

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    layout=((_UNIT, 8),),               # 48 layers
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=240,
    window=1024,
    rope_theta=1e6,
    vocab_pad_to=256,
    source="hf:google/gemma-3-1b-pt",
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma3-12b-smoke",
    layout=(((("local", "dense"),) * 2 + (("global", "dense"),), 2),),
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    window=16, remat=False)
