"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
Frontend stub: input_specs() provides merged (text+patch) embeddings and
3-stream M-RoPE position ids."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    layout=(((("global", "dense"),), 28),),
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    mrope=True,
    embed_inputs=False,       # vision/text merge stub
    rope_theta=1e6,
    vocab_pad_to=256,
    source="arXiv:2409.12191",
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-vl-2b-smoke",
    layout=(((("global", "dense"),), 2),),
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    remat=False)
