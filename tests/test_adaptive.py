"""Adaptive-governor subsystem tests: segmented-execution parity (an
N-segment run with constant params matches single-shot ``simulate()``
bit-for-bit, state and metrics, modulo the diagnostic loop counter),
zero-recompile protocol/workload switching (compile counter), drift
schedules, governor policies, governed runs, and the v2 results store."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adaptive import (DEFAULT_ARMS, EpsilonGreedyPolicy, FixedPolicy,
                            GovernorCell, Policy, QueueRulePolicy,
                            SegmentRecord, preset_timeline, run_governed)
from repro.core.lock import (CostModel, EngineConfig, WorkloadSpec,
                             extract, flash_crowd, hot_migration,
                             protocol_params, simulate, skew_ramp,
                             split_config, stationary)
from repro.core.lock import engine as E
from repro.core.lock.metrics import SimResult, delta_globals, extract_globals
from repro.core.lock.workload import DriftSchedule, gen_txn
from repro.sweep import load_results, save_results, summarize

ZIPF = WorkloadSpec(kind="zipf", txn_len=2, n_rows=256, zipf_s=0.9)
HORIZON = 30_000

METRIC_FIELDS = ("commits", "user_aborts", "forced_aborts", "lock_ops",
                 "tps", "mean_latency_us", "p95_latency_us", "abort_rate",
                 "lock_wait_frac", "cpu_util")


def run_segmented(cfg, n_seg, pad_threads=None, pad_len=None):
    stat, dp = split_config(cfg, pad_threads=pad_threads, pad_len=pad_len)
    s = E.init_state_dyn(stat, dp)
    for k in range(1, n_seg + 1):
        s, snap = E.run_segment(stat, dp, s, cfg.horizon * k // n_seg)
    return stat, s, snap


class TestSegmentedParity:
    def test_nseg_bitexact_vs_single_shot(self):
        """Constant-dp segmented run == simulate() in EVERY state leaf
        and metric; only Globals.iters may grow (<= one per boundary)."""
        cfg = EngineConfig(protocol=protocol_params("group"),
                           costs=CostModel(), workload=ZIPF,
                           n_threads=8, horizon=HORIZON)
        stat, dp = split_config(cfg)
        ref = E._run_dyn(stat, dp, E.init_state_dyn(stat, dp))
        n_seg = 5
        _, seg, _ = run_segmented(cfg, n_seg)
        ref_l = jax.tree.leaves(ref)
        seg_l = jax.tree.leaves(seg)
        iters_ref, iters_seg = int(ref.g.iters), int(seg.g.iters)
        mism = [i for i, (a, b) in enumerate(zip(ref_l, seg_l))
                if not bool((np.asarray(a) == np.asarray(b)).all())]
        # the only tolerated mismatch is the iters leaf
        iters_idx = [i for i, x in enumerate(jax.tree.leaves(ref))
                     if x is ref.g.iters]
        assert mism in ([], iters_idx), mism
        assert 0 <= iters_seg - iters_ref <= n_seg - 1

    def test_group_commit_pipeline_parity(self):
        """Regression: the group-commit queue drains one member per loop
        iteration, so splitting a BUSY step at a segment boundary used to
        accelerate the CWAIT->COMMIT pipeline (caught in review at
        group/fit/T=128: tstart/wstart/wait_ticks/lat_sum drifted).
        Boundaries must only ever split idle windows."""
        wl = WorkloadSpec(kind="fit", txn_len=2, n_rows=4096, n_hot=1)
        cfg = EngineConfig(protocol=protocol_params("group"),
                           costs=CostModel(), workload=wl,
                           n_threads=128, horizon=12_000)
        stat, dp = split_config(cfg)
        ref = E._run_dyn(stat, dp, E.init_state_dyn(stat, dp))
        _, seg, _ = run_segmented(cfg, 12)
        for grp, a, b in (("th", ref.th, seg.th), ("rows", ref.rows,
                          seg.rows), ("g", ref.g, seg.g)):
            for n in a._fields:
                if n == "iters":
                    continue
                assert (np.asarray(getattr(a, n))
                        == np.asarray(getattr(b, n))).all(), f"{grp}.{n}"

    def test_padded_parity_metrics(self):
        """Segments at a padded shape (threads AND op slots) produce the
        same metrics as the unpadded single-shot run."""
        cfg = EngineConfig(protocol=protocol_params("mysql"),
                           costs=CostModel(), workload=ZIPF,
                           n_threads=12, horizon=HORIZON, p_abort=0.05)
        _, seg, _ = run_segmented(cfg, 3, pad_threads=64, pad_len=4)
        got = extract_globals("mysql", 12, jax.device_get(seg.g))
        ref = extract("mysql", 12,
                      simulate("mysql", ZIPF, n_threads=12, horizon=HORIZON,
                               p_abort=0.05))
        for f in METRIC_FIELDS:
            assert getattr(got, f) == getattr(ref, f), f

    def test_segments_end_exactly_at_boundary(self):
        """A stalled system must pause AT the boundary (no idle-jump past
        it) so a governor can still act — zipf s0.9 multi-row writes
        deadlock-stall detection-free o2 within the horizon."""
        wl = dataclasses.replace(ZIPF, txn_len=4)
        cfg = EngineConfig(protocol=protocol_params("o2"),
                           costs=CostModel(), workload=wl,
                           n_threads=16, horizon=40_000)
        stat, dp = split_config(cfg)
        s = E.init_state_dyn(stat, dp)
        for until in (10_000, 20_000, 30_000):
            s, _ = E.run_segment(stat, dp, s, until)
            assert int(s.g.now) <= until
        # resumable: switching to a detection protocol unsticks the stall
        _, dp2 = split_config(dataclasses.replace(
            cfg, protocol=protocol_params("mysql")))
        c0 = int(s.g.commits)
        s, _ = E.run_segment(stat, dp2, s, 40_000)
        assert int(s.g.commits) > c0

    def test_delta_globals_splits_counters(self):
        cfg = EngineConfig(protocol=protocol_params("group"),
                           costs=CostModel(), workload=ZIPF,
                           n_threads=8, horizon=HORIZON)
        stat, dp = split_config(cfg)
        s = E.init_state_dyn(stat, dp)
        g0 = jax.device_get(s.g)
        s, _ = E.run_segment(stat, dp, s, HORIZON // 2)
        g1 = jax.device_get(s.g)
        s, _ = E.run_segment(stat, dp, s, HORIZON)
        g2 = jax.device_get(s.g)
        d01, d12 = delta_globals(g0, g1), delta_globals(g1, g2)
        assert int(d01.commits) + int(d12.commits) == int(g2.commits)
        assert int(d01.now) + int(d12.now) == int(g2.now)
        assert (np.asarray(d01.hist) + np.asarray(d12.hist)
                == np.asarray(g2.hist)).all()


class TestCompileCounter:
    def test_switches_cost_zero_recompiles(self):
        """Segment boundaries, protocol switches, workload drift, and new
        cells at the same shape all reuse ONE compiled program."""
        wl = dataclasses.replace(ZIPF, n_rows=251)    # unique shape: cold
        cfg = EngineConfig(protocol=protocol_params("o2"),
                           costs=CostModel(), workload=wl,
                           n_threads=8, horizon=20_000)
        stat, dp = split_config(cfg)
        n0 = E._run_seg_dyn._cache_size()
        s = E.init_state_dyn(stat, dp)
        s, _ = E.run_segment(stat, dp, s, 5_000)
        assert E._run_seg_dyn._cache_size() - n0 == 1
        for proto, zs, hb, until in (("mysql", 0.3, 0, 10_000),
                                     ("group", 1.1, 99, 15_000),
                                     ("bamboo", 0.7, 7, 20_000)):
            w2 = dataclasses.replace(wl, zipf_s=zs, hot_base=hb)
            _, dp2 = split_config(dataclasses.replace(
                cfg, protocol=protocol_params(proto), workload=w2))
            s, _ = E.run_segment(stat, dp2, s, until)
        s2 = E.init_state_dyn(stat, dp)          # a fresh cell, same shape
        E.run_segment(stat, dp, s2, 9_999)
        assert E._run_seg_dyn._cache_size() - n0 == 1


class TestDriftSchedules:
    def test_builders_shapes_and_compile_key(self):
        base = WorkloadSpec(kind="zipf", txn_len=2, n_rows=512)
        for ds in (stationary(base, 6), hot_migration(base, 6),
                   skew_ramp(base, 6), flash_crowd(base, 6, skew_hi=1.0)):
            assert ds.n_segments == 6
            keys = {(s.kind, s.n_rows, s.txn_len) for s in ds.specs}
            assert len(keys) == 1                 # stable compile key
        assert ds.spec(99) == ds.specs[-1]        # clamped

    def test_kind_change_rejected(self):
        a = WorkloadSpec(kind="zipf", txn_len=2)
        b = WorkloadSpec(kind="uniform", txn_len=2)
        with pytest.raises(AssertionError, match="compile key"):
            DriftSchedule("bad", (a, b))

    def test_hot_migration_moves_the_hot_row(self):
        base = WorkloadSpec(kind="hotspot_update", txn_len=2, n_rows=1024)
        ds = hot_migration(base, 8, n_sites=4, period=2)
        anchors = [s.hot_base for s in ds.specs]
        assert anchors == [0, 0, 256, 256, 512, 512, 768, 768]
        tids = jnp.arange(4, dtype=jnp.int32)
        ctr = jnp.zeros(4, jnp.int32)
        keys, _, _, _, _ = gen_txn(ds.spec(2), tids, ctr)
        assert (np.asarray(keys[:, 0]) == 256).all()   # op 0 hits the site
        keys0, _, _, _, _ = gen_txn(ds.spec(0), tids, ctr)
        assert (np.asarray(keys0[:, 0]) == 0).all()

    def test_skew_ramp_endpoints(self):
        ds = skew_ramp(WorkloadSpec(kind="zipf"), 5, lo=0.2, hi=1.0)
        assert ds.specs[0].zipf_s == 0.2 and ds.specs[-1].zipf_s == 1.0

    def test_flash_crowd_step(self):
        ds = flash_crowd(WorkloadSpec(kind="hotspot_mix"), 8, at=0.5,
                         write_lo=0.1, write_hi=0.9, skew_hi=1.2)
        wr = [s.write_ratio for s in ds.specs]
        assert wr == [0.1] * 4 + [0.9] * 4
        assert ds.specs[-1].zipf_s == 1.2 and ds.specs[0].zipf_s == 0.7


def _rec(index=0, preset="o2", tps=1e6, max_qlen=0, n_waiting=0,
         lock_wait_frac=0.0, n_threads=64):
    m = SimResult(protocol=preset, n_threads=n_threads, commits=1000,
                  user_aborts=0, forced_aborts=0, lock_ops=0,
                  sim_seconds=0.01, tps=tps, mean_latency_us=1.0,
                  p95_latency_us=1.0, p99_latency_us=1.0,
                  lock_wait_frac=lock_wait_frac, cpu_util=0.5,
                  abort_rate=0.0, iters=10)
    return SegmentRecord(index=index, t0=0, t1=1000, preset=preset,
                         metrics=m, max_qlen=max_qlen, n_hot=0,
                         n_live=0, n_waiting=n_waiting)


class TestPolicies:
    def test_fixed(self):
        p = FixedPolicy("group")
        p.reset(64)
        assert p.decide(0, []) == "group"
        assert p.decide(5, [_rec()]) == "group"

    def test_rule_branches(self):
        p = QueueRulePolicy()
        p.reset(64)
        assert p.decide(0, []) == "o2"
        # concentrated deep queue -> group locking (hotspot)
        assert p.decide(1, [_rec(max_qlen=60, n_waiting=62)]) == "group"
        # long but dispersed queues + most threads waiting -> detection
        assert p.decide(1, [_rec(max_qlen=25, n_waiting=60)]) == "mysql"
        # calm -> cheapest path
        assert p.decide(1, [_rec(preset="mysql", max_qlen=1, n_waiting=2,
                                 lock_wait_frac=0.01)]) == "o2"
        # ambiguous middle keeps the incumbent (hysteresis)
        assert p.decide(1, [_rec(preset="mysql", max_qlen=3, n_waiting=12,
                                 lock_wait_frac=0.2)]) == "mysql"

    def test_greedy_bootstrap_then_exploit(self):
        p = EpsilonGreedyPolicy(arms=DEFAULT_ARMS)
        p.reset(64)
        hist = []
        for k, (arm, tps) in enumerate(zip(DEFAULT_ARMS, (3e6, 2e6, 1e6))):
            got = p.decide(k, hist)
            assert got == arm                     # bootstrap in arm order
            hist.append(_rec(index=k, preset=arm, tps=tps))
        assert p.decide(3, hist) == "o2"          # exploit the best

    def test_greedy_drop_taints_family_and_reprobes(self):
        """Drive the policy segment-by-segment like the runner does: an
        o2 collapse must re-probe mysql but NOT family-mate group (which
        inherits the collapsed estimate)."""
        p = EpsilonGreedyPolicy(arms=DEFAULT_ARMS, drop_frac=0.5)
        p.reset(64)
        hist = []
        # (observed tps for the preset the policy chose at each step)
        script = {"o2": [3e6, 4e6, 10_000.0],
                  "group": [2.5e6], "mysql": [2e6, 1.5e6, 1.5e6]}
        chosen = []
        for k in range(7):
            arm = p.decide(k, hist)
            chosen.append(arm)
            hist.append(_rec(index=k, preset=arm,
                             tps=script[arm].pop(0)))
        # bootstrap o2/group/mysql, exploit o2, collapse, re-probe mysql,
        # exploit mysql — group is never probed again after the taint
        assert chosen == ["o2", "group", "mysql", "o2", "o2",
                          "mysql", "mysql"]
        assert p.est["group"] == 10_000.0


class TestRunGoverned:
    def test_fixed_stationary_cell_matches_simulate(self):
        """The governed path with a never-switching policy and stationary
        drift is the plain simulation, bit-for-bit (metrics)."""
        drift = stationary(ZIPF, 4)
        res = run_governed(
            [GovernorCell("cell", FixedPolicy("group"), drift, 8)],
            horizon=HORIZON, n_segments=4)
        ref = extract("group", 8,
                      simulate("group", ZIPF, n_threads=8, horizon=HORIZON))
        for f in METRIC_FIELDS:
            assert getattr(res["cell"], f) == getattr(ref, f), f

    def test_records_and_totals_consistent(self):
        # unique n_rows -> cold cache -> the compile count is exact
        drift = skew_ramp(dataclasses.replace(ZIPF, n_rows=257), 4,
                          lo=0.3, hi=1.1)
        res = run_governed(
            [GovernorCell("a", QueueRulePolicy(), drift, 8),
             GovernorCell("b", FixedPolicy("mysql"), drift, 8)],
            horizon=HORIZON, n_segments=4)
        assert res.n_compiles == 1                # one bucket, one program
        for name in ("a", "b"):
            segs = res.segments[name]
            assert len(segs) == 4
            # busy cells pause at their first event past each boundary;
            # nothing ever runs past the horizon
            for s, bound in zip(segs, (HORIZON * k // 4 for k in range(1, 5))):
                assert bound <= s["t1"] <= HORIZON
                assert s["t0"] < s["t1"]
            assert sum(s["commits"] for s in segs) == res[name].commits
            assert preset_timeline(res, name)[0] in ("o2", "mysql")
        rows = summarize(res)
        assert len(rows) == 2 and rows[0].startswith("a,")

    def test_packed_segment_substrate_bitexact_per_lane(self):
        """run_packed_segment (the substrate shared by the governed runner
        and the sweep compaction scheduler) must equal per-lane
        _run_seg_dyn in EVERY state leaf — heterogeneous protocols,
        drift-schedule workloads, per-lane untils, and the device-
        resident packed resume (``packed=``) included."""
        from repro.sweep.runner import run_packed_segment, _take
        drift = hot_migration(
            WorkloadSpec(kind="hotspot_update", txn_len=2, n_rows=1024),
            4, n_sites=4, period=1)
        cfg0 = EngineConfig(protocol=protocol_params("group"),
                            costs=CostModel(), workload=drift.spec(0),
                            n_threads=8, horizon=HORIZON)
        stat, _ = split_config(cfg0, pad_threads=64)
        protos = ("group", "mysql", "o2")
        dps, states = [], []
        for i, proto in enumerate(protos):
            _, dp = split_config(dataclasses.replace(
                cfg0, protocol=protocol_params(proto),
                workload=drift.spec(i)), pad_threads=64)
            dps.append(dp)
            states.append(E.init_state_dyn(stat, dp))
        untils = [10_000, 14_000, 18_000]
        packed, snaps, w = run_packed_segment(stat, dps, states, untils)
        assert w == 4                       # 3 lanes pow2-padded
        # second segment resumes from the packed stack, no re-pack
        untils2 = [20_000, 24_000, 28_000]
        packed2, snaps2, _ = run_packed_segment(stat, dps, None, untils2,
                                                packed=packed)
        for i in range(3):
            ref, ref_snap = E.run_segment(stat, dps[i], states[i],
                                          untils[i])
            for a, b in zip(jax.tree.leaves(_take(packed, i)),
                            jax.tree.leaves(ref)):
                assert (np.asarray(a) == np.asarray(b)).all()
            for a, b in zip(jax.tree.leaves(_take(snaps, i)),
                            jax.tree.leaves(ref_snap)):
                assert (np.asarray(a) == np.asarray(b)).all()
            ref2, _ = E.run_segment(stat, dps[i], ref, untils2[i])
            for a, b in zip(jax.tree.leaves(_take(packed2, i)),
                            jax.tree.leaves(ref2)):
                assert (np.asarray(a) == np.asarray(b)).all()

    def test_batched_lanes_match_sequential(self):
        """chunk_size>1 (vmapped segmented lanes) must be bit-identical
        to the sequential per-lane path, switches included."""
        drift = skew_ramp(ZIPF, 3, lo=0.3, hi=1.1)

        def cells():
            return [GovernorCell("r", QueueRulePolicy(), drift, 8),
                    GovernorCell("m", FixedPolicy("mysql"), drift, 12),
                    GovernorCell("g", FixedPolicy("group"), drift, 8)]

        seq = run_governed(cells(), horizon=HORIZON, n_segments=3,
                           chunk_size=1)
        bat = run_governed(cells(), horizon=HORIZON, n_segments=3,
                           chunk_size=4)
        for name in ("r", "m", "g"):
            for f in METRIC_FIELDS:
                assert getattr(seq[name], f) == getattr(bat[name], f), \
                    (name, f)
            assert seq.segments[name] == bat.segments[name]

    def test_duplicate_cell_names_rejected(self):
        drift = stationary(ZIPF, 2)
        cells = [GovernorCell("x", FixedPolicy("o2"), drift, 8)] * 2
        with pytest.raises(ValueError, match="duplicate"):
            run_governed(cells, horizon=1000, n_segments=2)


class TestStoreV3:
    def test_roundtrip_with_segments(self, tmp_path):
        drift = stationary(ZIPF, 3)
        res = run_governed(
            [GovernorCell("cell", FixedPolicy("o2"), drift, 8)],
            horizon=HORIZON, n_segments=3)
        path = os.path.join(tmp_path, "gov.json")
        save_results(path, res, meta={"tag": "t"})
        doc = load_results(path)
        assert doc["schema"] == "repro.sweep/v4"
        rec = doc["points"][0]
        assert len(rec["segments"]) == 3
        assert rec["segments"][0]["preset"] == "o2"
        assert rec["metrics"]["commits"] == res["cell"].commits
        # v3 additions: per-window breakdown conserves to pad_T * window,
        # distribution histograms count every row / every hot row
        pad_t = 64      # MIN_T_BUCKET pads the 8 threads up
        for seg in rec["segments"]:
            bd = seg["breakdown"]
            assert set(bd) == set(E.TB_NAMES)
            assert sum(bd.values()) == pad_t * (seg["t1"] - seg["t0"])
            assert sum(seg["wait_hist"]) == ZIPF.n_rows
            assert sum(seg["occ_hist"]) == seg["n_hot"]

    def test_v1_v2_documents_still_load(self, tmp_path):
        for old in ("repro.sweep/v1", "repro.sweep/v2"):
            path = os.path.join(tmp_path, old.replace("/", "_") + ".json")
            with open(path, "w") as f:
                json.dump({"schema": old, "points": []}, f)
            assert load_results(path)["schema"] == old

    def test_foreign_json_rejected(self, tmp_path):
        path = os.path.join(tmp_path, "x.json")
        with open(path, "w") as f:
            json.dump({"schema": "something/else"}, f)
        with pytest.raises(ValueError):
            load_results(path)


class TestBrookSwitchIn:
    """Switching INTO brook2pl mid-run (governor.py preset-table note):
    in-flight transactions generated under the previous preset can hold
    locks out of chop order and form a cycle pure brook2pl can never
    resolve (no detection, no timeouts). run_governed rejects such
    switches loudly (switch_safe); `brook_guard` re-arms the wait
    timeout as the residual resolver and must recover."""

    class _Switch(Policy):
        def __init__(self, first, then):
            self.first, self.then = first, then
            self.name = f"switch:{first}->{then}"

        def decide(self, k, history):
            return self.first if k == 0 else self.then

    W = WorkloadSpec(kind="zipf", zipf_s=1.1, txn_len=4, n_rows=256)

    def _run(self, then, n_seg=6):
        cell = GovernorCell(f"swt_{then}", self._Switch("mysql", then),
                            stationary(self.W, n_seg), 64)
        return run_governed([cell], horizon=240_000, n_segments=n_seg)

    def test_pure_brook_switch_in_rejected_loudly(self):
        """An unresolvable inherited stall must not be a silent flatline:
        the runner refuses the switch and names the safe preset."""
        from repro.adaptive import switch_safe
        assert not switch_safe("brook2pl")
        assert not switch_safe("brook_hold")
        assert switch_safe("brook_guard") and switch_safe("mysql")
        with pytest.raises(ValueError, match="brook_guard"):
            self._run("brook2pl")

    def test_brook_to_brook_switches_allowed(self):
        """Chop-ordered in-flight txns make resolver-free targets safe:
        brook_guard -> brook2pl must NOT be rejected (same acquisition
        order, nothing to inherit a cycle from)."""
        cell = GovernorCell("swt_gp", self._Switch("brook_guard",
                                                   "brook2pl"),
                            stationary(self.W, 4), 64)
        res = run_governed([cell], horizon=120_000, n_segments=4)
        assert res["swt_gp"].forced_aborts == 0
        assert res["swt_gp"].commits > 0

    def test_brook_guard_switch_in_recovers(self):
        """The guarded variant times the inherited cycle out and then
        runs deadlock-free brook traffic for the rest of the horizon."""
        res = self._run("brook_guard")
        commits = [s["commits"] for s in res.segments["swt_brook_guard"]]
        assert sum(commits[2:]) > 0, commits
        assert commits[-1] > 0, commits

    def test_two_hop_guard_bypass_rejected(self):
        """mysql -> brook_guard -> brook2pl: a one-segment guard hop
        does not launder unordered-era locks (its timeout may not have
        fired within the segment) — resolver-free presets require an
        ordered history all the way back to segment 0."""
        class _TwoHop(Policy):
            name = "twohop"

            def decide(self, k, history):
                return ("mysql", "brook_guard", "brook2pl")[min(k, 2)]

        cell = GovernorCell("swt_2hop", _TwoHop(), stationary(self.W, 4),
                            64)
        with pytest.raises(ValueError, match="unordered-preset"):
            run_governed([cell], horizon=120_000, n_segments=4)

    def test_rank_rotating_drift_rejected_for_pure_brook(self):
        """hot_migration rotates acq_rank between segments: in-flight
        and new transactions would disagree about the lock order with no
        resolver (measured: permanent flatline) — must raise instead,
        while brook_guard rides the same drift fine."""
        drift = hot_migration(self.W, 6, n_sites=2, period=1)
        cell = GovernorCell("mig_brook", FixedPolicy("brook2pl"), drift,
                            64)
        with pytest.raises(ValueError, match="rank"):
            run_governed([cell], horizon=120_000, n_segments=6)
        cell2 = GovernorCell("mig_guard", FixedPolicy("brook_guard"),
                             drift, 64)
        res = run_governed([cell2], horizon=120_000, n_segments=6)
        assert res["mig_guard"].commits > 0

    def test_stable_rank_drift_allowed_for_pure_brook(self):
        """skew_ramp changes zipf_s but never the key-heat ORDER, so the
        rank table is stable and fixed brook2pl stays legal and clean."""
        w = dataclasses.replace(self.W, zipf_s=0.7)
        drift = skew_ramp(w, 4, lo=0.3, hi=0.9)
        cell = GovernorCell("ramp_brook", FixedPolicy("brook2pl"), drift,
                            64)
        res = run_governed([cell], horizon=120_000, n_segments=4)
        assert res["ramp_brook"].forced_aborts == 0
        assert res["ramp_brook"].dd_ticks == 0
        assert res["ramp_brook"].commits > 0

    def test_fixed_brook_guard_no_false_timeouts(self):
        """The guard timeout must never fire on brook-generated waits:
        a brook_guard run from segment 0 pays zero forced aborts (the
        property the preset comment claims). At 240k/6 the derived
        guard sits on GUARD_FLOOR — this is also the floor's no-false-
        timeout certification."""
        from repro.adaptive import GUARD_FLOOR, guard_timeout
        assert guard_timeout(240_000, 6) == GUARD_FLOOR
        cell = GovernorCell("fx_guard", FixedPolicy("brook_guard"),
                            stationary(self.W, 6), 64)
        res = run_governed([cell], horizon=240_000, n_segments=6)
        assert res["fx_guard"].forced_aborts == 0
        assert res["fx_guard"].dd_ticks == 0
        assert res["fx_guard"].commits > 0

    def test_guard_timeout_derivation(self):
        """guard_timeout = half a segment clamped to [floor, cap]; the
        derivation only rewrites presets that re-arm the timeout as a
        resolver (brook_guard), never protocol-semantic timeouts."""
        from repro.adaptive import (GUARD_CAP, GUARD_FLOOR, guard_timeout,
                                    preset_params)
        assert guard_timeout(480_000, 4) == 60_000
        assert guard_timeout(240_000, 6) == GUARD_FLOOR      # clamp up
        assert guard_timeout(2_000_000, 4) == GUARD_CAP      # clamp down
        g = preset_params("brook_guard", horizon=480_000, n_segments=4)
        assert g.wait_timeout == 60_000
        assert g.commit_wait_timeout == 60_000
        # context-free callers keep the fixed fallback
        assert preset_params("brook_guard").wait_timeout == 100_000
        # semantic timeouts untouched: mysql's default, brook2pl's 0
        assert preset_params("mysql", horizon=480_000,
                             n_segments=4).wait_timeout == \
            preset_params("mysql").wait_timeout
        assert preset_params("brook2pl", horizon=480_000,
                             n_segments=4).wait_timeout == 0
        # derived guard still counts as switch-safe (resolver present)
        from repro.adaptive import switch_safe
        assert switch_safe("brook_guard")

    def test_brook_guard_last_boundary_switch_recovers(self):
        """The ROADMAP case the fixed 100k guard could not serve: a
        switch-in at the LAST segment boundary of a coarse-segment run.
        Segments of 120k ticks derive a 60k guard — the inherited stall
        times out with half the final segment left, so the tail segment
        still commits. (The fixed guard would fire 100k in, leaving
        only noise-level room before the horizon.)"""
        from repro.adaptive import guard_timeout
        n_seg, horizon = 4, 480_000
        assert guard_timeout(horizon, n_seg) == 60_000

        class _LastHop(Policy):
            name = "lasthop"

            def decide(self, k, history):
                return "brook_guard" if k == n_seg - 1 else "mysql"

        cell = GovernorCell("swt_late", _LastHop(),
                            stationary(self.W, n_seg), 64)
        res = run_governed([cell], horizon=horizon, n_segments=n_seg)
        segs = res.segments["swt_late"]
        assert segs[-1]["preset"] == "brook_guard"
        assert segs[-1]["commits"] > 0, [s["commits"] for s in segs]
