"""Integration: prefill + decode must equal the full forward (f32) for all
architectures — validates KV caches, ring buffers, absorbed MLA decode,
RG-LRU and SSD state carry."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, ARCHS
from repro.models import lm_spec, init_params, forward, prefill, decode_step

B, S = 2, 24


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              act_dtype="float32", capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = init_params(lm_spec(cfg), key)
    kw_full, kw_pre, kw_dec = {}, {}, {}
    if cfg.embed_inputs:
        toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
        kw_full, kw_pre, kw_dec = (dict(tokens=toks),
                                   dict(tokens=toks[:, :S]),
                                   dict(tokens=toks[:, S:S + 1]))
    else:
        em = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.float32)
        kw_full, kw_pre, kw_dec = (dict(embeds=em),
                                   dict(embeds=em[:, :S]),
                                   dict(embeds=em[:, S:S + 1]))
    if cfg.mrope:
        p3 = jnp.broadcast_to(jnp.arange(S + 1, dtype=jnp.int32),
                              (3, B, S + 1))
        kw_full["positions3"] = p3
        kw_pre["positions3"] = p3[:, :, :S]
        kw_dec["positions3"] = p3[:, :, S:S + 1]
    cfg_f = dataclasses.replace(cfg, ssm_chunk=1) \
        if arch == "mamba2-1.3b" else cfg
    out_full = forward(params, cfg_f, mode="prefill", **kw_full)
    _, caches = prefill(params, cfg, max_len=S + 1, **kw_pre)
    logits_dec, new_caches = decode_step(
        params, cfg, caches=caches, pos=jnp.asarray(S, jnp.int32), **kw_dec)
    a = out_full.logits[:, -1]
    b = logits_dec[:, 0]
    err = float(jnp.abs(a - b).max())
    scale = float(jnp.abs(a).max()) + 1e-6
    assert err / scale < 2e-4, (arch, err, scale)
    # caches keep their shapes (decode is steady-state)
    for x, y in zip(jax.tree.leaves(caches), jax.tree.leaves(new_caches)):
        assert x.shape == y.shape


def test_chunked_paths_match_dense():
    for arch in ["deepseek-coder-33b", "gemma3-12b",
                 "deepseek-v2-lite-16b"]:
        cfg = dataclasses.replace(get_config(arch, smoke=True),
                                  act_dtype="float32", attn_chunk=8)
        cfg0 = dataclasses.replace(cfg, attn_chunk=0)
        key = jax.random.PRNGKey(0)
        params = init_params(lm_spec(cfg), key)
        toks = jax.random.randint(key, (B, 32), 0, cfg.vocab)
        a = forward(params, cfg, tokens=toks, mode="train").logits
        b = forward(params, cfg0, tokens=toks, mode="train").logits
        assert float(jnp.abs(a - b).max()) < 1e-4, arch


def test_chunked_ce_matches_dense():
    import numpy as np
    from repro.models import loss_fn
    arch = "qwen2-0.5b"
    key = jax.random.PRNGKey(0)
    cfg0 = dataclasses.replace(get_config(arch, smoke=True),
                               act_dtype="float32", zloss=0.0)
    cfg1 = dataclasses.replace(cfg0, loss_chunk=8)
    params = init_params(lm_spec(cfg0), key)
    batch = {"tokens": jax.random.randint(key, (B, 32), 0, cfg0.vocab),
             "labels": jax.random.randint(key, (B, 32), 0, cfg0.vocab)}
    l0, _ = loss_fn(params, cfg0, batch)
    l1, _ = loss_fn(params, cfg1, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
