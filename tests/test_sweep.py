"""Sweep-subsystem tests: bit-exact parity between vmapped sweep lanes and
per-config ``simulate()`` runs (the subsystem's core contract), padding /
masking invariance for heterogeneous grids, compile accounting, grid
builders, and the JSON results store."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.lock import (CostModel, WorkloadSpec, extract, extract_aria,
                             simulate, simulate_aria)
from repro.sweep import (expand, grid, load_results, point, run_sweep,
                         save_results, summarize, zip_grid)

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
ZIPF = WorkloadSpec(kind="zipf", txn_len=2, n_rows=256, zipf_s=0.9)
HORIZON = 25_000

INT_FIELDS = ("commits", "user_aborts", "forced_aborts", "lock_ops")
FLOAT_FIELDS = ("tps", "mean_latency_us", "p95_latency_us", "abort_rate",
                "lock_wait_frac", "cpu_util")


def reference(p):
    """Per-config result via the plain simulate() path."""
    if p.protocol == "aria":
        s = simulate_aria(p.workload, p.n_threads, costs=p.costs,
                          horizon=p.horizon)
        return extract_aria(p.n_threads, s)
    s = simulate(p.protocol, p.workload, p.n_threads, costs=p.costs,
                 horizon=p.horizon, p_abort=p.p_abort, **p.over())
    return extract(p.protocol, p.n_threads, s)


def assert_bitexact(r_sweep, r_ref, name):
    for f in INT_FIELDS:
        assert getattr(r_sweep, f) == getattr(r_ref, f), (name, f)
    for f in FLOAT_FIELDS:
        assert getattr(r_sweep, f) == getattr(r_ref, f), (name, f)


class TestParity:
    def test_vmapped_grid_matches_simulate_bitexact(self):
        """Heterogeneous protocols/threads/p_abort, forced vmap chunks:
        every lane must equal its per-config run bit-for-bit (threads are
        padded to the 64-floor bucket, so padding is exercised too)."""
        pts = grid(["mysql", "group", "bamboo"], HOT, [8, 12],
                   horizon=HORIZON, p_abort=[0.0, 0.1],
                   name_fmt="{protocol}_T{n_threads}_p{p_abort}")
        res = run_sweep(pts, chunk_size=4)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_heterogeneous_txn_len_padding(self):
        """Mixed txn lengths land in distinct buckets; zipf keys flow
        through the traced CDF identically on both paths."""
        pts = [point("group", ZIPF, 8, horizon=HORIZON, name="zl2"),
               point("group", dataclasses.replace(ZIPF, txn_len=4), 8,
                     horizon=HORIZON, name="zl4")]
        res = run_sweep(pts, chunk_size=2)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_max_bucket_pads_txn_len(self):
        """thread_bucket="max" runs the short-txn lane with padded op
        slots (L=2 lane in an L=4 program) — padding must stay bitwise
        invisible (nops stops the op cursor before padded slots)."""
        pts = [point("mysql", ZIPF, 8, horizon=HORIZON, name="mx2"),
               point("mysql", dataclasses.replace(ZIPF, txn_len=4), 12,
                     horizon=HORIZON, name="mx4")]
        res = run_sweep(pts, chunk_size=2, thread_bucket="max")
        assert len(res.buckets) == 1
        assert res.buckets[0].pad_len == 4
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_aria_lanes_match(self):
        pts = grid("aria", HOT, [8, 16], horizon=HORIZON)
        res = run_sweep(pts, chunk_size=2)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_proto_override_flows_through(self):
        pts = [point("group", HOT, 16, horizon=HORIZON, name="gc_off",
                     group_commit=False)]
        res = run_sweep(pts)
        assert_bitexact(res["gc_off"], reference(pts[0]), "gc_off")

    def test_aria_rejects_unsupported_params(self):
        """Aria has no abort injection/drain; a sweep must refuse rather
        than silently run defaults under a name that claims them."""
        pts = [point("aria", HOT, 8, horizon=HORIZON, p_abort=0.1,
                     name="aria_p0.1")]
        with pytest.raises(ValueError, match="aria does not support"):
            run_sweep(pts)


class TestCompileAccounting:
    def test_64_grid_one_compile_per_bucket(self):
        """A 64-config (protocol x threads x p_abort x costs) grid over one
        shape bucket: chunked vmap execution, exactly one engine compile
        (unique n_rows guarantees a cold cache for this shape)."""
        w = dataclasses.replace(HOT, n_rows=509)
        pts = grid(["mysql", "o1", "o2", "group"], w, [4, 8, 16, 32],
                   horizon=15_000, p_abort=[0.0, 0.05],
                   costs=[CostModel(), CostModel(sync_lat=1_000)],
                   name_fmt="{protocol}_T{n_threads}_p{p_abort}_s{sync_lat}")
        assert len(pts) == 64
        res = run_sweep(pts, chunk_size=16)
        assert len(res.buckets) == 1        # one shape bucket (T floor 64)
        assert res.buckets[0].n_chunks == 4
        assert res.n_compiles == 1
        # sampled per-config parity on the same grid
        rng = np.random.default_rng(0)
        for i in rng.choice(len(pts), size=4, replace=False):
            assert_bitexact(res[pts[i].name], reference(pts[i]),
                            pts[i].name)

    def test_chunk_reuse_second_sweep_compiles_nothing(self):
        w = dataclasses.replace(HOT, n_rows=509)
        pts = grid(["mysql", "o2"], w, [4, 8], horizon=15_000)
        run_sweep(pts, chunk_size=4)
        res2 = run_sweep(pts, chunk_size=4)
        assert res2.n_compiles == 0


class TestGridBuilders:
    def test_cartesian_counts_and_names(self):
        pts = grid(["mysql", "o2"], {"hot": HOT}, [8, 16], horizon=1000,
                   p_abort=[0.0, 0.1],
                   name_fmt="{protocol}_{workload}_T{n_threads}_p{p_abort}")
        assert len(pts) == 8
        assert len({p.name for p in pts}) == 8
        assert pts[0].name.startswith(("mysql_hot", "o2_hot"))

    def test_zip_grid_pairs_and_broadcasts(self):
        pts = zip_grid(["mysql", "o2", "group"], HOT, 8, horizon=1000,
                       costs=[CostModel(sync_lat=s) for s in (0, 10, 20)])
        assert len(pts) == 3
        assert [p.costs.sync_lat for p in pts] == [0, 10, 20]
        with pytest.raises(ValueError):
            zip_grid(["mysql", "o2"], HOT, [1, 2, 3], horizon=1000)

    def test_expand_workload_fields(self):
        ws = expand(ZIPF, tag_fmt="sf{zipf_s}", zipf_s=[0.7, 0.99])
        assert [t for t, _ in ws] == ["sf0.7", "sf0.99"]
        assert ws[1][1].zipf_s == 0.99

    def test_duplicate_names_rejected(self):
        pts = grid("mysql", HOT, 8, horizon=1000) * 2
        with pytest.raises(ValueError, match="duplicate"):
            run_sweep(pts)


class TestStore:
    def test_roundtrip(self, tmp_path):
        pts = grid(["mysql", "o2"], HOT, 8, horizon=HORIZON)
        res = run_sweep(pts)
        path = os.path.join(tmp_path, "sweep.json")
        save_results(path, res, meta={"tag": "t"})
        doc = load_results(path)
        assert doc["meta"]["tag"] == "t"
        assert doc["n_points"] == 2
        names = [r["name"] for r in doc["points"]]
        assert names == [p.name for p in pts]
        rec = doc["points"][0]
        assert rec["metrics"]["commits"] == res[rec["name"]].commits
        assert rec["workload"]["kind"] == "hotspot_update"
        # summarize emits one benchmark CSV row per point, in order
        rows = summarize(res)
        assert len(rows) == 2 and rows[0].startswith(pts[0].name + ",")

    def test_load_rejects_foreign_json(self, tmp_path):
        path = os.path.join(tmp_path, "x.json")
        with open(path, "w") as f:
            json.dump({"hello": 1}, f)
        with pytest.raises(ValueError):
            load_results(path)
