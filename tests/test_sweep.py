"""Sweep-subsystem tests: bit-exact parity between vmapped sweep lanes and
per-config ``simulate()`` runs (the subsystem's core contract) on both the
sort-then-cut and lockstep-compaction execution paths, padding / masking
invariance for heterogeneous grids, lane sharding, compile accounting,
grid builders, and the JSON results store."""
import dataclasses
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core.lock import (CostModel, WorkloadSpec, extract, extract_aria,
                             simulate, simulate_aria)
from repro.sweep import (expand, grid, load_results, point, run_sweep,
                         save_results, summarize, zip_grid)

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
ZIPF = WorkloadSpec(kind="zipf", txn_len=2, n_rows=256, zipf_s=0.9)
HORIZON = 25_000

INT_FIELDS = ("commits", "user_aborts", "forced_aborts", "lock_ops",
              "iters", "dd_ticks")
FLOAT_FIELDS = ("tps", "mean_latency_us", "p95_latency_us", "abort_rate",
                "lock_wait_frac", "cpu_util")


def reference(p):
    """Per-config result via the plain simulate() path."""
    if p.protocol == "aria":
        s = simulate_aria(p.workload, p.n_threads, costs=p.costs,
                          horizon=p.horizon)
        return extract_aria(p.n_threads, s)
    s = simulate(p.protocol, p.workload, p.n_threads, costs=p.costs,
                 horizon=p.horizon, p_abort=p.p_abort, drain=p.drain,
                 **p.over())
    return extract(p.protocol, p.n_threads, s)


def assert_bitexact(r_sweep, r_ref, name):
    for f in INT_FIELDS:
        assert getattr(r_sweep, f) == getattr(r_ref, f), (name, f)
    for f in FLOAT_FIELDS:
        assert getattr(r_sweep, f) == getattr(r_ref, f), (name, f)


class TestParity:
    def test_vmapped_grid_matches_simulate_bitexact(self):
        """Heterogeneous protocols/threads/p_abort, forced vmap chunks:
        every lane must equal its per-config run bit-for-bit (threads are
        padded to the 64-floor bucket, so padding is exercised too)."""
        pts = grid(["mysql", "group", "bamboo"], HOT, [8, 12],
                   horizon=HORIZON, p_abort=[0.0, 0.1],
                   name_fmt="{protocol}_T{n_threads}_p{p_abort}")
        res = run_sweep(pts, chunk_size=4)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_heterogeneous_txn_len_padding(self):
        """Mixed txn lengths land in distinct buckets; zipf keys flow
        through the traced CDF identically on both paths."""
        pts = [point("group", ZIPF, 8, horizon=HORIZON, name="zl2"),
               point("group", dataclasses.replace(ZIPF, txn_len=4), 8,
                     horizon=HORIZON, name="zl4")]
        res = run_sweep(pts, chunk_size=2)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_max_bucket_pads_txn_len(self):
        """thread_bucket="max" runs the short-txn lane with padded op
        slots (L=2 lane in an L=4 program) — padding must stay bitwise
        invisible (nops stops the op cursor before padded slots)."""
        pts = [point("mysql", ZIPF, 8, horizon=HORIZON, name="mx2"),
               point("mysql", dataclasses.replace(ZIPF, txn_len=4), 12,
                     horizon=HORIZON, name="mx4")]
        res = run_sweep(pts, chunk_size=2, thread_bucket="max")
        assert len(res.buckets) == 1
        assert res.buckets[0].pad_len == 4
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_aria_lanes_match(self):
        pts = grid("aria", HOT, [8, 16], horizon=HORIZON)
        res = run_sweep(pts, chunk_size=2)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_proto_override_flows_through(self):
        pts = [point("group", HOT, 16, horizon=HORIZON, name="gc_off",
                     group_commit=False)]
        res = run_sweep(pts)
        assert_bitexact(res["gc_off"], reference(pts[0]), "gc_off")

    def test_aria_rejects_unsupported_params(self):
        """Aria has no abort injection/drain; a sweep must refuse rather
        than silently run defaults under a name that claims them."""
        pts = [point("aria", HOT, 8, horizon=HORIZON, p_abort=0.1,
                     name="aria_p0.1")]
        with pytest.raises(ValueError, match="aria does not support"):
            run_sweep(pts)

    def test_unknown_protocol_fails_loudly(self):
        """A typo'd protocol must raise up front, not degrade silently
        (the old _est_iters bare-except hid it behind a worse chunking
        order until a cryptic KeyError deep in the bucket loop)."""
        pts = [point("br00k2pl", HOT, 8, horizon=1000, name="b2pl")]
        with pytest.raises(ValueError, match="unknown protocol"):
            run_sweep(pts)

    def test_brook2pl_lanes_match_simulate_bitexact(self):
        """brook2pl is a first-class sweep protocol now (PR 4 made it a
        ValueError): vmapped lanes — chop-ordered acquisition, per-op
        release, injected aborts — must equal per-config ``simulate()``
        bit-for-bit in one compile per shape bucket, with zero deadlock
        rollbacks and zero detection ticks."""
        w = dataclasses.replace(ZIPF, n_rows=251)   # unique shape: cold
        pts = grid(["brook2pl", "mysql"], w, [8, 12], horizon=HORIZON,
                   p_abort=[0.0, 0.1],
                   name_fmt="{protocol}_T{n_threads}_p{p_abort}")
        res = run_sweep(pts, chunk_size=4)
        assert len(res.buckets) == 1
        assert res.n_compiles <= 4          # the pow2 width ladder, once
        for p in pts:
            r = res[p.name]
            assert_bitexact(r, reference(p), p.name)
            if p.protocol == "brook2pl":
                assert r.forced_aborts == 0 and r.dd_ticks == 0, p.name

    def test_est_iters_covers_brook2pl_without_warning(self):
        """The analytic model covers the new protocol, so the warn-once
        fallback must NOT fire on brook2pl sweeps (satellite: the warn
        path is for protocols that land BEFORE their ref model)."""
        from repro.sweep import runner as R
        R._EST_WARNED.clear()
        pts = grid(["brook2pl"], HOT, [8, 64], horizon=HORIZON)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ests = [R._est_iters(p) for p in pts]
        assert all(e > 0 for e in ests)
        assert ests[0] > 0 and not w, [str(x.message) for x in w]
        # denser-thread config never estimates below the single lane
        assert ests[1] >= ests[0] * 0.99

    def test_est_iters_ref_model_gap_warns_once_and_falls_back(self,
                                                               monkeypatch):
        """A protocol the analytic model doesn't cover degrades the
        scheduling estimate with ONE warning — while real bugs (any other
        exception type) propagate."""
        from repro.sweep import runner as R
        import repro.core.lock.ref_engine as ref

        def boom(*a, **k):
            raise ValueError("no chain model for this knob combo")

        monkeypatch.setattr(ref, "predicted_tps", boom)
        R._EST_WARNED.clear()
        pts = grid(["mysql", "o2"], HOT, [8, 12], horizon=HORIZON)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ests = [R._est_iters(p) for p in pts]
        assert all(e > 0 for e in ests)
        assert len([x for x in w if x.category is RuntimeWarning]) == 2
        # one warning per protocol, not per point

        def bug(*a, **k):
            raise TypeError("a real bug")

        monkeypatch.setattr(ref, "predicted_tps", bug)
        R._EST_WARNED.clear()
        with pytest.raises(TypeError, match="a real bug"):
            R._est_iters(pts[0])


class TestCompaction:
    """The lockstep-compaction scheduler (default whenever chunk_size > 1)
    must be bit-identical to per-config ``simulate()`` — including the
    ``iters`` diagnostic, since pausing a lane at an iteration budget and
    resuming replays the identical step sequence — while paying fewer
    vmapped lane-iterations on mixed-density grids."""

    def test_partial_pack_replicated_pad(self):
        """5 lanes in an 8-wide request: the pack pads to pow2 by
        replicating the last lane; padded copies must stay invisible."""
        pts = grid(["mysql", "group", "o2", "bamboo", "o1"], HOT, 8,
                   horizon=HORIZON)
        res = run_sweep(pts, chunk_size=8, compact=True)
        assert all(b.compacted for b in res.buckets)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_thread_and_txn_len_padded_lanes(self):
        """Compacted lanes at padded shapes (T to the pow2-64 floor, L to
        the max-bucket cap) keep padding bitwise invisible."""
        pts = [point("mysql", ZIPF, 8, horizon=HORIZON, name="mz2"),
               point("group", dataclasses.replace(ZIPF, txn_len=4), 12,
                     horizon=HORIZON, name="gz4"),
               point("o2", dataclasses.replace(ZIPF, txn_len=4), 24,
                     horizon=HORIZON, name="oz4")]
        res = run_sweep(pts, chunk_size=4, compact=True,
                        thread_bucket="max")
        assert len(res.buckets) == 1
        assert res.buckets[0].pad_len == 4
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_drain_lanes_retire_on_quiescence(self):
        """drain=True lanes end when every thread HALTs (not at the
        horizon), so the host-side retire check must track the device
        cond's live-threads clause."""
        pts = grid(["mysql", "group"], HOT, [4, 8], horizon=12_000,
                   drain=True, name_fmt="d_{protocol}_T{n_threads}")
        res = run_sweep(pts, chunk_size=4, compact=True)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)

    def test_aria_barrier_path_staggered_costs(self):
        """Aria lanes with different batch times (sync_lat axis) retire at
        staggered calls; segmented batch execution must replay the exact
        batch sequence."""
        pts = zip_grid("aria", HOT, [8, 8, 16], horizon=HORIZON,
                       costs=[CostModel(), CostModel(sync_lat=3_000),
                              CostModel(sync_lat=9_000)],
                       name_fmt="aria_T{n_threads}_s{sync_lat}")
        # 16-batch slices: the sync_lat=9000 lane (~3 batches total)
        # retires on call 1 while the sync_lat=0 lane (~80) keeps going
        res = run_sweep(pts, chunk_size=4, compact=True, slice_iters=16)
        for p in pts:
            assert_bitexact(res[p.name], reference(p), p.name)
        assert res.n_repacks >= 1       # short lanes left the pack early

    def test_mixed_density_cuts_lane_iters_2x(self):
        """The acceptance scenario: detection-free protocols deadlock-stall
        on multi-row zipf at T>=16 (tens of iterations) while detection
        protocols churn (thousands) — a mix the iteration ESTIMATE cannot
        see, so sort-then-cut locksteps them. Compaction must cut total
        vmapped lane-iterations >= 2x and repack at least once, while
        staying bit-identical."""
        w = dataclasses.replace(ZIPF, n_rows=512)
        mk = lambda pr, t: point(pr, w, t, horizon=60_000,
                                 name=f"{pr}_T{t}")
        pts = [mk("o1", 16), mk("mysql", 16),
               mk("o2", 16), mk("o2", 32), mk("o2", 64),
               mk("group", 16), mk("group", 32), mk("group", 64)]
        res_n = run_sweep(pts, chunk_size=8, compact=False)
        res_c = run_sweep(pts, chunk_size=8, compact=True)
        for p in pts:
            ref = reference(p)
            assert_bitexact(res_c[p.name], ref, p.name)
            assert_bitexact(res_n[p.name], ref, p.name)
        assert res_c.n_repacks >= 1
        assert res_n.lane_iters >= 2 * res_c.lane_iters, \
            (res_n.lane_iters, res_c.lane_iters)
        # the store carries the per-call repack log
        log = res_c.buckets[0].repack_log
        assert log and all(len(rec) == 3 for rec in log)

    def test_adaptive_budget_recovers_from_bad_estimate(self, monkeypatch):
        """PR4 follow-on (b): with `slice_iters` unset the budget
        re-derives from the observed per-call progress, so an analytic
        estimate that's 1000x off costs a handful of re-calibrated calls
        — not total-iters/256 fixed slices (what `slice_iters=256` pins,
        standing in for the old static behavior). Parity must hold on
        every path and mixed-density repack counts must not regress."""
        from repro.sweep import runner as R
        w = dataclasses.replace(ZIPF, n_rows=512)
        mk = lambda pr, t: point(pr, w, t, horizon=120_000,
                                 name=f"{pr}_T{t}")
        pts = [mk("o1", 16), mk("mysql", 16), mk("o2", 16),
               mk("group", 16)]
        monkeypatch.setattr(R, "_est_iters", lambda p: 1.0)
        res_static = run_sweep(pts, chunk_size=4, compact=True,
                               slice_iters=256)
        res_adapt = run_sweep(pts, chunk_size=4, compact=True)
        for p in pts:
            ref = reference(p)
            assert_bitexact(res_adapt[p.name], ref, p.name)
            assert_bitexact(res_static[p.name], ref, p.name)
        calls_a = sum(b.n_chunks for b in res_adapt.buckets)
        calls_s = sum(b.n_chunks for b in res_static.buckets)
        assert calls_a < calls_s, (calls_a, calls_s)
        # compaction still engages: the stalled detection-free lanes
        # retire early and the pack repacks down, adaptive or not
        assert res_adapt.n_repacks >= 1
        # re-deriving the budget must not blow up the lockstep cost
        assert res_adapt.lane_iters <= int(1.25 * res_static.lane_iters)


SUB_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = SUB_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    return out.stdout


class TestLaneSharding:
    def test_nondividing_lane_count_pads_and_engages(self):
        """Regression: _shard_lanes used to silently skip sharding when
        n_lanes % n_dev != 0 (e.g. 12 lanes on 8 devices ran on one
        device). It must now pad the lane axis to a device multiple
        (replicated tail) and place lanes across the whole mesh — and
        sweep results must stay bit-identical. 3 forced host devices so
        pow2 pack widths never divide evenly."""
        code = (
            "import os;"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=3';"
            "import jax, numpy as np, jax.numpy as jnp;"
            "from repro.core.lock import WorkloadSpec, simulate, extract;"
            "from repro.sweep import grid, run_sweep;"
            "from repro.sweep import runner as R;"
            "assert len(jax.devices()) == 3;"
            "tree = {'x': jnp.arange(8.).reshape(4, 2)};"
            "sh, g = R._shard_lanes(tree, 4);"
            "assert g == 6, g;"
            "assert sh['x'].shape == (6, 2), sh['x'].shape;"
            "x = np.asarray(sh['x']);"
            "assert (x[4] == x[3]).all() and (x[5] == x[3]).all();"
            "assert len(sh['x'].sharding.device_set) == 3;"
            "HOT = WorkloadSpec(kind='hotspot_update', txn_len=1,"
            " n_rows=512);"
            "pts = grid(['mysql', 'o2', 'group'], HOT, [8, 12],"
            " horizon=20_000, name_fmt='{protocol}_T{n_threads}');"
            "res_c = run_sweep(pts, chunk_size=4);"
            "res_n = run_sweep(pts, chunk_size=4, compact=False);\n"
            "for p in pts:\n"
            "  r = extract(p.protocol, p.n_threads, simulate(p.protocol,"
            " p.workload, p.n_threads, horizon=p.horizon))\n"
            "  for res in (res_c, res_n):\n"
            "    got = res[p.name]\n"
            "    assert (got.commits, got.iters, got.tps) =="
            " (r.commits, r.iters, r.tps), p.name\n"
            "print('sharded-parity-ok', res_c.n_repacks)\n"
        )
        out = _run_sub(code)
        assert "sharded-parity-ok" in out


class TestCompileAccounting:
    def test_64_grid_one_compile_per_bucket(self):
        """A 64-config (protocol x threads x p_abort x costs) grid over one
        shape bucket: chunked vmap execution, exactly one engine compile
        (unique n_rows guarantees a cold cache for this shape)."""
        w = dataclasses.replace(HOT, n_rows=509)
        pts = grid(["mysql", "o1", "o2", "group"], w, [4, 8, 16, 32],
                   horizon=15_000, p_abort=[0.0, 0.05],
                   costs=[CostModel(), CostModel(sync_lat=1_000)],
                   name_fmt="{protocol}_T{n_threads}_p{p_abort}_s{sync_lat}")
        assert len(pts) == 64
        res = run_sweep(pts, chunk_size=16, compact=False)
        assert len(res.buckets) == 1        # one shape bucket (T floor 64)
        assert res.buckets[0].n_chunks == 4
        assert res.n_compiles == 1
        # sampled per-config parity on the same grid
        rng = np.random.default_rng(0)
        for i in rng.choice(len(pts), size=4, replace=False):
            assert_bitexact(res[pts[i].name], reference(pts[i]),
                            pts[i].name)

    def test_compacted_width_ladder_bounds_executables(self):
        """Compaction trades the chunked path's single executable for a
        bounded pow2 width ladder: full packs at chunk_size, the drain
        tail at shrinking pow2 widths — never more than
        log2(chunk) + 2 programs per cold shape."""
        w = dataclasses.replace(HOT, n_rows=503)    # unique shape: cold
        pts = grid(["mysql", "o1", "o2", "group"], w, [4, 8, 16],
                   horizon=15_000, name_fmt="{protocol}_T{n_threads}")
        res = run_sweep(pts, chunk_size=8, compact=True, slice_iters=64)
        assert res.n_compiles <= 5          # widths {8,4,2} + _run_dyn + 1
        # the same sweep again reuses every ladder executable
        res2 = run_sweep(pts, chunk_size=8, compact=True, slice_iters=64)
        assert res2.n_compiles == 0

    def test_chunk_reuse_second_sweep_compiles_nothing(self):
        w = dataclasses.replace(HOT, n_rows=509)
        pts = grid(["mysql", "o2"], w, [4, 8], horizon=15_000)
        run_sweep(pts, chunk_size=4)
        res2 = run_sweep(pts, chunk_size=4)
        assert res2.n_compiles == 0


class TestGridBuilders:
    def test_cartesian_counts_and_names(self):
        pts = grid(["mysql", "o2"], {"hot": HOT}, [8, 16], horizon=1000,
                   p_abort=[0.0, 0.1],
                   name_fmt="{protocol}_{workload}_T{n_threads}_p{p_abort}")
        assert len(pts) == 8
        assert len({p.name for p in pts}) == 8
        assert pts[0].name.startswith(("mysql_hot", "o2_hot"))

    def test_zip_grid_pairs_and_broadcasts(self):
        pts = zip_grid(["mysql", "o2", "group"], HOT, 8, horizon=1000,
                       costs=[CostModel(sync_lat=s) for s in (0, 10, 20)])
        assert len(pts) == 3
        assert [p.costs.sync_lat for p in pts] == [0, 10, 20]
        with pytest.raises(ValueError):
            zip_grid(["mysql", "o2"], HOT, [1, 2, 3], horizon=1000)

    def test_expand_workload_fields(self):
        ws = expand(ZIPF, tag_fmt="sf{zipf_s}", zipf_s=[0.7, 0.99])
        assert [t for t, _ in ws] == ["sf0.7", "sf0.99"]
        assert ws[1][1].zipf_s == 0.99

    def test_duplicate_names_rejected(self):
        pts = grid("mysql", HOT, 8, horizon=1000) * 2
        with pytest.raises(ValueError, match="duplicate"):
            run_sweep(pts)


class TestStore:
    def test_roundtrip(self, tmp_path):
        pts = grid(["mysql", "o2"], HOT, 8, horizon=HORIZON)
        res = run_sweep(pts)
        path = os.path.join(tmp_path, "sweep.json")
        save_results(path, res, meta={"tag": "t"})
        doc = load_results(path)
        assert doc["meta"]["tag"] == "t"
        assert doc["n_points"] == 2
        names = [r["name"] for r in doc["points"]]
        assert names == [p.name for p in pts]
        rec = doc["points"][0]
        assert rec["metrics"]["commits"] == res[rec["name"]].commits
        assert rec["workload"]["kind"] == "hotspot_update"
        # summarize emits one benchmark CSV row per point, in order
        rows = summarize(res)
        assert len(rows) == 2 and rows[0].startswith(pts[0].name + ",")

    def test_load_rejects_foreign_json(self, tmp_path):
        path = os.path.join(tmp_path, "x.json")
        with open(path, "w") as f:
            json.dump({"hello": 1}, f)
        with pytest.raises(ValueError):
            load_results(path)
