"""Tests for the correctness analysis subsystem (repro.analysis).

Positive direction: the lint passes clean on every registered entry
point, and the certifier proves every protocol's discipline on real
traces across seeds and workload kinds. Negative direction — the part
that makes the positive direction mean something — the planted leak
FAILS the lint, and cyclic / corrupted traces are REJECTED.
"""
import numpy as np
import pytest

from repro.analysis import cli as acli
from repro.analysis import isolation as ISO
from repro.analysis import jaxpr_lint as JL
from repro.core.lock.costs import PROTOCOLS, protocol_params
from repro.core.lock.workload import WorkloadSpec
from repro.obs.trace import simulate_traced

W_ZIPF = WorkloadSpec(kind="zipf", n_rows=256, txn_len=4, zipf_s=1.1)
TIMEOUTS = dict(wait_timeout=8_000, commit_wait_timeout=8_000)


def _over(proto: str) -> dict:
    # brook2pl's timeout=0 IS the protocol; everyone else gets short
    # timeouts so detection-free deadlocks resolve inside the horizon
    return {} if proto == "brook2pl" else dict(TIMEOUTS)


# ---------------------------------------------------------------------------
# jaxpr lint
# ---------------------------------------------------------------------------

class TestLint:
    def test_all_registered_entry_points_clean(self):
        """The repo invariant, enforced: every entry point lowers to the
        byte-identical jaxpr across value-only config variants and
        passes every rule walk."""
        rep = JL.run_lint()
        assert rep.ok, rep.text()
        assert len(rep.entries) >= 13

    def test_registry_covers_compile_log_entries(self):
        """The lint registry mirrors compile_log._jitted(): every
        module-level registered jit is linted (instance-level _EXTRA
        registrations are runtime-scoped and exempt)."""
        names = {ep.name for ep in JL.default_entry_points()}
        for want in ("engine._run_dyn", "engine._run_batch",
                     "engine._run_seg_dyn", "engine._run_seg_batch",
                     "aria._run_dyn", "aria._run_batch",
                     "aria._run_seg_dyn", "aria._run_seg_batch",
                     "trace._run_traced", "serving._hist_add",
                     "kernels.flash_attention",
                     "kernels.flash_attention_bhsd",
                     "kernels.grouped_scatter_apply",
                     "kernels.segment_sums"):
            assert want in names, f"{want} missing from lint registry"

    def test_leaky_entry_point_fails(self):
        """Negative control: a wrapper that Python-folds wait_timeout
        before the jit boundary must produce a leak finding naming the
        failure mode."""
        findings = JL.lint_entry(JL.leaky_entry_point())
        assert any(f.rule in ("value-leak", "static-leak")
                   for f in findings), findings

    def test_concretized_knob_fails_loudly(self):
        """int(traced) inside the entry raises at lowering; the lint
        reports it as a finding instead of crashing."""
        import jax.numpy as jnp
        from repro.core.lock import engine as E

        def build(v):
            stat, dp = JL._split(v)
            return (stat,), (dp, E.init_state_dyn(stat, dp))

        def concretizing(stat, dp, s0):
            wt = int(dp.wait_timeout)           # ConcretizationTypeError
            return E._run_dyn.__wrapped__(
                stat, dp._replace(wait_timeout=jnp.asarray(wt)), s0)

        ep = JL.EntryPoint("negative.concretizing", concretizing, build)
        findings = JL.lint_entry(ep)
        assert any(f.rule == "concretized" for f in findings), findings

    def test_cond_count_rule_fires_on_mismatch(self):
        """Pinning a wrong expected count produces a cond-count finding
        that names the registry sites — the tripwire for a protocol
        branch getting folded or forked."""
        base = next(ep for ep in JL.default_entry_points()
                    if ep.name == "engine._run_dyn")
        wrong = JL.EntryPoint(base.name, base.fn, base.build,
                              cond_count=base.cond_count + 1)
        findings = JL.lint_entry(wrong)
        assert any(f.rule == "cond-count" for f in findings), findings

    def test_cond_sites_match_protocol_registry(self):
        """Every cond site is gated by a real traced flag — either a
        ProtocolParams field or a DynParams run knob (contention_attrib
        is gated by EngineConfig.attrib)."""
        pp = protocol_params("mysql")
        for site, flag in JL.PROTOCOL_COND_SITES.items():
            assert hasattr(pp, flag) or flag in JL.E.DynParams._fields, \
                (site, flag)


# ---------------------------------------------------------------------------
# serializability certifier
# ---------------------------------------------------------------------------

class TestCertifier:
    @pytest.mark.parametrize("proto", PROTOCOLS)
    def test_matrix_certifies(self, proto):
        """6 protocols x 3 seeds x 3 workload kinds, with injected
        aborts: every run certifies under its protocol's discipline and
        no run has a dirty edge."""
        saw_commits = saw_aborts = 0
        for kind in acli.KINDS:
            for seed in acli.SEEDS:
                c = ISO.certify_run(
                    proto, acli._workload(kind, seed), acli.THREADS,
                    horizon=acli.HORIZON, p_abort=0.05, seed=seed,
                    **_over(proto))
                assert c.ok, c.text()
                assert not c.dirty_edges
                saw_commits += c.n_committed
                saw_aborts += c.n_aborted
        # the matrix must actually exercise both terminators
        assert saw_commits > 0
        assert saw_aborts > 0

    def test_abort_event_counts_match_engine(self):
        """The new abort trace event fires exactly once per rollback:
        event count == user_aborts + forced_aborts."""
        s, tb = simulate_traced("mysql", W_ZIPF, 16, horizon=40_000,
                                p_abort=0.1, seed=2, cap=65_536,
                                **TIMEOUTS)
        from repro.obs.trace import EV_ABORT, events_host
        ev = events_host(tb)
        n_abort_ev = int((ev["ev"] == EV_ABORT).sum())
        assert n_abort_ev == int(s.g.user_aborts) + int(s.g.forced_aborts)
        assert n_abort_ev > 0

    def test_brook2pl_chop_mode(self):
        """brook2pl certifies in chop-piece mode: txn-level ww cycles
        are present (the chopping signature) while hold intervals stay
        mutually exclusive and ranks ascend."""
        c = ISO.certify_run("brook2pl", W_ZIPF, 16, horizon=40_000,
                            p_abort=0.05, seed=1)
        assert c.mode == "chop-piece"
        assert c.ok, c.text()
        assert c.chop_ww_cycles     # expected, informational
        # strict protocols never report chop cycles
        c2 = ISO.certify_run("mysql", W_ZIPF, 16, horizon=40_000,
                             seed=1, **TIMEOUTS)
        assert c2.mode == "txn-ww" and not c2.chop_ww_cycles

    def test_brook_rank_check_rejects_descending(self):
        """A synthetic attempt requesting rows against the rank order is
        flagged (the discipline check is live, not vacuous)."""
        from repro.obs.trace import EV_COMMIT, EV_GRANT, EV_WAIT_ENTER
        ev = [(0, 0, 5, EV_WAIT_ENTER), (1, 0, 5, EV_GRANT),
              (2, 0, 2, EV_WAIT_ENTER), (3, 0, 2, EV_GRANT),
              (9, 0, -1, EV_COMMIT)]
        events = {"ts": np.array([e[0] for e in ev]),
                  "tid": np.array([e[1] for e in ev]),
                  "row": np.array([e[2] for e in ev]),
                  "ev": np.array([e[3] for e in ev]),
                  "n": len(ev), "dropped": 0, "cap": len(ev)}
        rank = list(range(8))       # rank == row id; 5 -> 2 descends
        c = ISO.certify(events, protocol_params("brook2pl"),
                        acq_rank=rank)
        assert any("brook-rank" in v for v in c.violations), c.text()

    def test_cyclic_trace_rejected(self):
        c = ISO.certify(acli.cyclic_events(), "mysql")
        assert not c.serializable
        assert c.cycle is not None
        assert not c.ok

    def test_corrupted_trace_rejected(self):
        c = ISO.certify(acli.corrupted_events(), "mysql")
        assert not c.ok
        assert any("input-invalid" in v for v in c.violations)

    def test_dropped_trace_is_lower_bound(self):
        """A capacity-truncated trace still certifies its prefix but
        says so."""
        _s, tb = simulate_traced("mysql", W_ZIPF, 16, horizon=40_000,
                                 seed=1, cap=64, **TIMEOUTS)
        assert int(tb.dropped) > 0
        c = ISO.certify(tb, "mysql")
        assert c.lower_bound

    def test_selftest_passes(self):
        assert acli.run_selftest(verbose=False) == []


# ---------------------------------------------------------------------------
# satellite: trace-vs-breakdown wait accounting property
# ---------------------------------------------------------------------------

def _wait_bound_holds(proto, kind, p_abort, seed) -> tuple:
    W = WorkloadSpec(kind=kind, n_rows=256, txn_len=4, zipf_s=1.1,
                     n_warehouses=4, seed=seed)
    s, tb = simulate_traced(proto, W, 16, horizon=40_000,
                            p_abort=p_abort, seed=seed, cap=65_536,
                            **_over(proto))
    trace_wait = ISO.total_trace_wait_ticks(tb)
    tb_lock_wait = int(np.asarray(s.g.tb, dtype=np.int64)[:, 1].sum())
    return trace_wait, tb_lock_wait


class TestWaitAccountingProperty:
    """Trace-derived wait spans can never exceed what the engine charged
    to the lock_wait TickBreakdown bin (cold+hot): every resolved span
    covers ticks the thread provably spent in a wait phase, and
    unresolved/dropped spans only shrink the trace side."""

    @pytest.mark.parametrize("proto,kind,p_abort", [
        ("mysql", "zipf", 0.0), ("mysql", "tpcc", 0.1),
        ("o1", "hotspot_update", 0.05), ("group", "zipf", 0.05),
        ("bamboo", "tpcc", 0.0), ("brook2pl", "zipf", 0.05),
    ])
    def test_trace_wait_bounded_by_breakdown(self, proto, kind, p_abort):
        trace_wait, tb_lock_wait = _wait_bound_holds(proto, kind,
                                                     p_abort, seed=2)
        assert trace_wait <= tb_lock_wait
        if trace_wait:              # and the bound is not vacuous
            assert tb_lock_wait > 0

    def test_trace_wait_property_fuzzed(self):
        """Hypothesis twin of the parametrized cases (skips where the
        environment lacks hypothesis — the deterministic cases above
        always run)."""
        pytest.importorskip(
            "hypothesis", reason="hypothesis not installed; the "
            "parametrized twin covers the property deterministically")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=10, deadline=None)
        @given(proto=st.sampled_from(PROTOCOLS),
               kind=st.sampled_from(("zipf", "tpcc", "hotspot_update")),
               p_abort=st.sampled_from((0.0, 0.05, 0.1)),
               seed=st.integers(min_value=0, max_value=7))
        def prop(proto, kind, p_abort, seed):
            trace_wait, tb_lock_wait = _wait_bound_holds(proto, kind,
                                                         p_abort, seed)
            assert trace_wait <= tb_lock_wait

        prop()
