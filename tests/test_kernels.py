"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.grouped_scatter import (segment_sums, segment_sums_ref,
                                           grouped_scatter_apply,
                                           grouped_apply_ref)
from repro.kernels.flash_attention import flash_attention, attention_ref

RNG = np.random.default_rng(42)


class TestGroupedScatter:
    @pytest.mark.parametrize("n,d,g", [(64, 8, 4), (700, 130, 37),
                                       (1024, 256, 1), (33, 7, 33),
                                       (512, 64, 100)])
    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_segment_sums_sweep(self, n, d, g, dtype):
        seg = jnp.asarray(np.sort(RNG.integers(0, g, n)).astype(np.int32))
        upd = jnp.asarray(RNG.normal(size=(n, d)).astype(dtype))
        got = segment_sums(seg, upd, g)
        want = segment_sums_ref(seg, upd, g)
        # long f32 reductions differ by accumulation order (blocked vs
        # sequential); tolerance per the long_reduction guidance
        tol = 2e-4 if dtype == np.float32 else 2e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_unsorted_ids_also_work(self):
        seg = jnp.asarray(RNG.integers(0, 9, 200).astype(np.int32))
        upd = jnp.asarray(RNG.normal(size=(200, 16)).astype(np.float32))
        np.testing.assert_allclose(segment_sums(seg, upd, 9),
                                   segment_sums_ref(seg, upd, 9),
                                   rtol=1e-5, atol=1e-5)

    def test_negative_ids_dropped(self):
        seg = jnp.asarray(np.array([-1, 0, 0, 2, -1], np.int32))
        upd = jnp.ones((5, 4), jnp.float32)
        got = segment_sums(seg, upd, 3)
        np.testing.assert_allclose(np.asarray(got)[:, 0], [2, 0, 1])

    @pytest.mark.parametrize("hotness", [0, 200, 1800])
    def test_end_to_end_hot_apply(self, hotness):
        V, N, D = 300, 2048, 32
        ids = RNG.integers(0, V, N).astype(np.int32)
        if hotness:
            ids[:hotness] = 5
        ids = jnp.asarray(ids)
        upd = jnp.asarray(RNG.normal(size=(N, D)).astype(np.float32))
        table = jnp.asarray(RNG.normal(size=(V, D)).astype(np.float32))
        got = grouped_scatter_apply(table, ids, upd, threshold=32)
        want = grouped_apply_ref(table, ids, upd)
        # tolerance sized for f32 accumulation-order drift at 1800 adds/key
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("shape", [
        (2, 64, 64, 4, 2, 32),      # GQA
        (1, 128, 128, 8, 8, 64),    # MHA
        (2, 96, 96, 6, 1, 16),      # MQA
        (1, 256, 256, 2, 2, 128),   # long-ish
    ])
    @pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
    def test_sweep_vs_ref(self, shape, dtype):
        B, Sq, Sk, H, K, D = shape
        dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
        q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), dt)
        k = jnp.asarray(RNG.normal(size=(B, Sk, K, D)), dt)
        v = jnp.asarray(RNG.normal(size=(B, Sk, K, D)), dt)
        got = flash_attention(q, k, v, causal=True)
        want = attention_ref(q, k, v, causal=True)
        tol = 2e-6 if dt == jnp.float32 else 2e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_noncausal(self):
        q = jnp.asarray(RNG.normal(size=(1, 64, 2, 32)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 64, 2, 32)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 64, 2, 32)), jnp.float32)
        np.testing.assert_allclose(
            flash_attention(q, k, v, causal=False),
            attention_ref(q, k, v, causal=False), rtol=2e-6, atol=2e-6)

    def test_matches_model_attention_path(self):
        """The kernel slot in gqa_attend agrees with the jnp path."""
        import dataclasses
        import jax
        from repro.configs import get_config
        from repro.models.attention import gqa_spec, gqa_attend
        from repro.models.common import init_params
        cfg = get_config("deepseek-coder-33b", smoke=True)
        p = init_params(gqa_spec(cfg), __import__("jax").random.PRNGKey(0))
        x = jnp.asarray(RNG.normal(size=(2, 64, cfg.d_model)), jnp.float32)
        a, _ = gqa_attend(p, x, cfg, "global", "train", use_kernel=False)
        b, _ = gqa_attend(p, x, cfg, "global", "train", use_kernel=True)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
