"""CC-engine behavior tests: protocol separation, oracle agreement,
figure-shape assertions (the paper's qualitative claims as tests)."""
import jax.numpy as jnp
import pytest

from repro.core.lock import (simulate, extract, WorkloadSpec, CostModel,
                             simulate_aria, extract_aria)
from repro.core.lock.ref_engine import predicted_tps

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)


def tps(proto, T, horizon=250_000, costs=None, **kw):
    s = simulate(proto, HOT, n_threads=T, horizon=horizon,
                 costs=costs or CostModel(), **kw)
    return extract(proto, T, s).tps


class TestParserShapes:
    """Fig 2a: MySQL at high concurrency is slower than serial."""

    def test_mysql_collapses_below_serial(self):
        assert tps("mysql", 256) < tps("mysql", 1) * 0.5

    def test_o1_beats_mysql_under_contention(self):
        assert tps("o1", 256) > tps("mysql", 256) * 1.5

    def test_o2_flat_in_threads(self):
        a, b = tps("o2", 64), tps("o2", 512)
        assert abs(a - b) / a < 0.1

    def test_group_beats_everything_hot(self):
        g = tps("group", 256)
        assert g > tps("o2", 256) * 2
        assert g > tps("mysql", 256) * 5
        assert g > tps("bamboo", 256) * 2

    def test_group_equals_o2_below_threshold(self):
        # hotspot never promotes with few threads (queue < 32)
        assert abs(tps("group", 8) - tps("o2", 8)) < 1e-6

    def test_bamboo_good_low_bad_high(self):
        """Fig 8: Bamboo helps at low concurrency, saturates at high."""
        assert tps("bamboo", 64) > tps("mysql", 64) * 1.5
        assert tps("bamboo", 1024) < tps("group", 1024) * 0.5


class TestOracle:
    @pytest.mark.parametrize("proto", ["mysql", "o1", "o2", "group",
                                       "bamboo"])
    @pytest.mark.parametrize("T", [1, 128])
    def test_engine_matches_analytic(self, proto, T):
        got = tps(proto, T, horizon=400_000)
        want = predicted_tps(proto, T, CostModel())
        assert got == pytest.approx(want, rel=0.15), (proto, T)


class TestReplication:
    """Fig 9: group commit amortizes the sync latency."""

    def test_sync_ratio(self):
        cm = CostModel(op_exec=500, sync_lat=10_000)
        g = tps("group", 256, horizon=3_000_000, costs=cm)
        m = tps("mysql", 256, horizon=3_000_000, costs=cm)
        assert 10 < g / m < 40        # paper: 22.3x

    def test_group_commit_off_serializes(self):
        cm = CostModel(op_exec=500, sync_lat=10_000)
        off = tps("group", 128, horizon=3_000_000, costs=cm,
                  group_commit=False)
        on = tps("group", 128, horizon=3_000_000, costs=cm)
        assert on > off * 3


class TestAborts:
    def test_injected_aborts_cascade_under_group(self):
        s = simulate("group", HOT, n_threads=64, horizon=300_000,
                     p_abort=0.02)
        r = extract("group", 64, s)
        # cascades amplify: forced aborts >> injected ones
        assert r.forced_aborts > r.user_aborts * 3

    def test_no_cascades_under_2pl(self):
        s = simulate("mysql", HOT, n_threads=64, horizon=300_000,
                     p_abort=0.02)
        r = extract("mysql", 64, s)
        assert r.forced_aborts == 0


class TestAria:
    def test_flat_scaling(self):
        r64 = extract_aria(64, simulate_aria(HOT, 64, horizon=400_000))
        r512 = extract_aria(512, simulate_aria(HOT, 512, horizon=400_000))
        assert r64.tps == pytest.approx(r512.tps, rel=0.05)

    def test_single_winner_per_batch(self):
        r = extract_aria(64, simulate_aria(HOT, 64, horizon=400_000))
        assert r.abort_rate > 0.9     # one hotspot -> one winner

    def test_skew_rollbacks(self):
        w = WorkloadSpec(kind="zipf", zipf_s=0.99, txn_len=4, n_rows=8192)
        r = extract_aria(256, simulate_aria(w, 256, horizon=400_000))
        assert r.abort_rate > 0.2     # paper: >20% at skew 0.99


class TestLockOps:
    def test_group_locking_reduces_lock_ops(self):
        """Fig 6d: group locking creates far fewer locks."""
        sm = simulate("mysql", HOT, n_threads=256, horizon=250_000)
        sg = simulate("group", HOT, n_threads=256, horizon=250_000)
        rm = extract("mysql", 256, sm)
        rg = extract("group", 256, sg)
        assert rg.lock_ops / max(rg.commits, 1) < \
            0.5 * rm.lock_ops / max(rm.commits, 1)
