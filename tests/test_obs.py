"""Observability-layer tests (DESIGN.md §11): trace-off bit-exactness,
zero-recompile capacity changes, overflow semantics, tick conservation,
and export validity."""
import json

import jax
import numpy as np
import pytest

from repro.adaptive import FixedPolicy, GovernorCell, run_governed
from repro.core.lock import (CostModel, WorkloadSpec, extract, simulate)
from repro.core.lock import engine as E
from repro.core.lock.workload import hot_migration
from repro.obs import (EV_COMMIT, EV_VICTIM, EV_WAIT_ENTER,
                       check_conservation, events_host, fractions,
                       make_trace, run_traced, simulate_traced, tick_sum,
                       to_chrome_trace, wait_profile)
from repro.obs import trace as obs_trace
from repro.obs.export import _wait_spans
from repro.sweep.runner import MIN_T_BUCKET, _pow2ceil

ZIPF = WorkloadSpec(kind="zipf", txn_len=4, n_rows=512, zipf_s=0.9)
HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
PROTOCOLS = ["mysql", "o1", "o2", "group", "bamboo", "brook2pl"]
HORIZON = 60_000


def leaves(tree):
    return jax.tree_util.tree_leaves(tree)


class TestTraceOffParity:
    """trace_on=False must be the stock engine, bit for bit — the whole
    layer is opt-in (ISSUE acceptance gate)."""

    @pytest.mark.parametrize("proto", PROTOCOLS)
    def test_bit_exact_off(self, proto):
        s_ref = simulate(proto, ZIPF, n_threads=24, horizon=HORIZON)
        s_off, tb = simulate_traced(proto, ZIPF, n_threads=24,
                                    horizon=HORIZON, trace_on=False)
        for a, b in zip(leaves(s_off), leaves(s_ref)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert int(tb.n) == 0 and int(tb.dropped) == 0

    def test_bit_exact_even_on(self):
        # tracing only *reads* StepEvents; SimState never depends on the
        # buffer, so even trace_on=True leaves the run unchanged
        s_ref = simulate("mysql", ZIPF, n_threads=24, horizon=HORIZON)
        s_on, _ = simulate_traced("mysql", ZIPF, n_threads=24,
                                  horizon=HORIZON)
        for a, b in zip(leaves(s_on), leaves(s_ref)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


class TestCompileKey:
    def test_cap_on_protocol_share_one_executable(self):
        """Capacity, the on-switch, and the protocol are traced data —
        one (shape, alloc) bucket compiles exactly once."""
        simulate_traced("mysql", ZIPF, n_threads=24, horizon=5_000,
                        cap=4096, alloc=4096)          # warm the bucket
        n0 = obs_trace._run_traced._cache_size()
        for proto in PROTOCOLS:
            for cap, on in [(64, True), (4096, True), (4096, False)]:
                simulate_traced(proto, ZIPF, n_threads=24, horizon=5_000,
                                cap=cap, alloc=4096, trace_on=on)
        assert obs_trace._run_traced._cache_size() == n0

    def test_classic_path_untouched_by_events_refactor(self):
        # the untraced entry points still route through the event-free
        # wrapper: running simulate() must not compile _run_traced
        n0 = obs_trace._run_traced._cache_size()
        simulate("o2", ZIPF, n_threads=24, horizon=5_000)
        assert obs_trace._run_traced._cache_size() == n0


class TestOverflow:
    def test_drops_preserve_prefix(self):
        _, big = simulate_traced("mysql", ZIPF, n_threads=24,
                                 horizon=HORIZON, cap=4096, alloc=4096)
        _, small = simulate_traced("mysql", ZIPF, n_threads=24,
                                   horizon=HORIZON, cap=64, alloc=4096)
        ev_b, ev_s = events_host(big), events_host(small)
        assert ev_b["dropped"] == 0 and ev_b["n"] > 64
        assert ev_s["n"] == 64
        assert ev_s["dropped"] == ev_b["n"] - 64
        for col in ("ts", "tid", "row", "ev"):
            assert np.array_equal(ev_s[col], ev_b[col][:64]), col

    def test_time_ordered(self):
        _, tb = simulate_traced("mysql", ZIPF, n_threads=24,
                                horizon=HORIZON, cap=4096)
        ts = events_host(tb)["ts"]
        assert np.all(np.diff(ts) >= 0)

    def test_commit_events_match_commit_count(self):
        s, tb = simulate_traced("group", ZIPF, n_threads=24,
                                horizon=HORIZON, cap=16_384)
        ev = events_host(tb)
        assert ev["dropped"] == 0
        r = extract("group", 24, s)
        assert int(np.sum(ev["ev"] == EV_COMMIT)) == r.commits

    def test_mysql_zipf_has_deadlock_victims(self):
        _, tb = simulate_traced("mysql", ZIPF, n_threads=24,
                                horizon=HORIZON, cap=16_384)
        ev = events_host(tb)
        assert int(np.sum(ev["ev"] == EV_VICTIM)) >= 1


class TestConservation:
    """sum(TickBreakdown) == padded_T x elapsed ticks, exactly."""

    @pytest.mark.parametrize("proto", PROTOCOLS)
    def test_simulate(self, proto):
        s = simulate(proto, ZIPF, n_threads=24, horizon=HORIZON)
        check_conservation(s, int(s.th.phase.shape[0]))

    def test_with_drain_and_costs(self):
        s = simulate("group", HOT, n_threads=64, horizon=HORIZON,
                     drain=True, costs=CostModel(sync_lat=2_000))
        pad_t = int(s.th.phase.shape[0])
        check_conservation(s, pad_t)
        # drain runs past the horizon; elapsed is whatever now says
        assert tick_sum(s) == pad_t * int(s.g.now)

    def test_aborts(self):
        s = simulate("o2", ZIPF, n_threads=24, horizon=HORIZON,
                     p_abort=0.05)
        check_conservation(s, int(s.th.phase.shape[0]))

    def test_fractions_sum_to_one(self):
        s = simulate("mysql", HOT, n_threads=64, horizon=HORIZON)
        r = extract("mysql", 64, s)
        assert sum(fractions(r.breakdown).values()) == pytest.approx(1.0)

    def test_every_governed_segment_conserves(self):
        """Per-window deltas conserve too (drifting workload, resumable
        segments) — the v3 store rows are balanced books, not just the
        final totals."""
        drift = hot_migration(ZIPF, 4, n_sites=4, period=1)
        res = run_governed(
            [GovernorCell("c", FixedPolicy("mysql"), drift, 12)],
            horizon=48_000, n_segments=4)
        pad_t = _pow2ceil(12, MIN_T_BUCKET)
        segs = res.segments["c"]
        assert len(segs) == 4
        for seg in segs:
            window = seg["t1"] - seg["t0"]
            assert window > 0
            assert sum(seg["breakdown"].values()) == pad_t * window
            assert sum(seg["wait_hist"]) == ZIPF.n_rows
            assert sum(seg["occ_hist"]) == seg["n_hot"]


class TestSnapshotHistograms:
    def test_wait_hist_counts_all_rows(self):
        cfg = E.EngineConfig(
            protocol=E.protocol_params("mysql"), costs=CostModel(),
            workload=ZIPF, n_threads=24, horizon=HORIZON)
        stat, dp = E.split_config(cfg)
        s0 = E.init_state_dyn(stat, dp)
        _, _, snap = run_traced(stat, dp, s0, make_trace(256))
        wait_hist = np.asarray(snap.wait_hist)
        occ_hist = np.asarray(snap.occ_hist)
        assert int(wait_hist.sum()) == ZIPF.n_rows
        assert int(occ_hist.sum()) == int(snap.n_hot)
        # contended zipf: some rows must have non-empty wait queues
        assert int(wait_hist[1:].sum()) > 0


class TestExport:
    def _events(self):
        _, tb = simulate_traced("mysql", ZIPF, n_threads=24,
                                horizon=HORIZON, cap=16_384)
        return events_host(tb)

    def test_chrome_trace_valid_json(self):
        ev = self._events()
        doc = to_chrome_trace(ev, label="test")
        doc2 = json.loads(json.dumps(doc))    # round-trips
        assert doc2["traceEvents"]
        for e in doc2["traceEvents"]:
            assert e["ph"] in ("M", "X", "i")
            if e["ph"] == "X":
                assert e["dur"] >= 0 and e["ts"] >= 0
        assert doc2["otherData"]["dropped"] == 0

    def test_wait_spans_cover_wait_enters(self):
        ev = self._events()
        n_spans = sum(1 for _ in _wait_spans(ev))
        assert n_spans == int(np.sum(ev["ev"] == EV_WAIT_ENTER))

    def test_wait_profile_report(self):
        txt = wait_profile(self._events(), top_k=5)
        lines = txt.splitlines()
        assert lines[0].startswith("# wait profile")
        header = lines[1].split(",")
        assert header[0] == "row" and "deadlock_victim" in header
        assert len(lines) <= 2 + 5

    def test_wait_profile_warns_on_drop(self):
        _, tb = simulate_traced("mysql", ZIPF, n_threads=24,
                                horizon=HORIZON, cap=64, alloc=4096)
        assert "WARNING" in wait_profile(tb)
