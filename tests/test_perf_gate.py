"""Perf-gate + bench-JSON plumbing tests: the gate's pass/fail contract
(wall thresholds, exact compile counts at equal scope, error/new-module
handling), the ``run.py --only --json`` merge path, and the
roofline-table filters."""
import json

import pytest

from benchmarks import perf_gate, roofline_table
from benchmarks.run import _parse_row, _top_fns, merge_only_doc


def _mod(wall=100.0, compiles=3, quick=True, scope="suite", **extra):
    d = {"wall_s": wall, "compiles": compiles, "quick": quick,
         "scope": scope, "peak_rss_mb": 100.0, "rows": [], "sweeps": []}
    d.update(extra)
    return d


def _doc(**mods):
    return {"quick": True, "modules": mods,
            "total_wall_s": sum(m.get("wall_s", 0.0) for m in mods.values())}


# ---------------------------------------------------------------- gate ---

def test_gate_passes_on_identical_docs():
    doc = _doc(fig02=_mod(), fig15=_mod(wall=50.0))
    ok, lines = perf_gate.compare(doc, doc)
    assert ok and lines[-1] == "gate: PASS"


def test_gate_fails_on_2x_wall_regression():
    base = _doc(fig02=_mod(wall=100.0))
    fresh = _doc(fig02=_mod(wall=200.0))
    ok, lines = perf_gate.compare(base, fresh)
    assert not ok
    assert any(l.startswith("FAIL fig02: wall") for l in lines)


def test_gate_wall_slack_absorbs_small_module_noise():
    # 2x of a 3s module is within the 5s slack — tiny modules don't flap
    base = _doc(kernels=_mod(wall=3.0))
    fresh = _doc(kernels=_mod(wall=6.0))
    ok, _ = perf_gate.compare(base, fresh)
    assert ok


def test_gate_speedup_never_fails_but_is_noted():
    base = _doc(fig06=_mod(wall=200.0))
    fresh = _doc(fig06=_mod(wall=20.0))
    ok, lines = perf_gate.compare(base, fresh)
    assert ok
    assert any("re-baselining" in l for l in lines)


def test_gate_compile_count_exact_at_equal_scope():
    base = _doc(fig02=_mod(compiles=3))
    fresh = _doc(fig02=_mod(compiles=4))
    ok, lines = perf_gate.compare(base, fresh)
    assert not ok
    assert any("compiles 4 != baseline 3" in l for l in lines)
    # scope mismatch: count difference is informational, not gating
    fresh2 = _doc(fig02=_mod(compiles=4, scope="only:fig02"))
    ok2, lines2 = perf_gate.compare(base, fresh2)
    assert ok2
    assert any("compile count not compared" in l for l in lines2)
    # legacy baseline without scope marker: also not gated
    legacy = _doc(fig02=_mod(compiles=3, scope=None))
    ok3, _ = perf_gate.compare(legacy, fresh)
    assert ok3


def test_gate_fresh_error_fails():
    base = _doc(fig02=_mod())
    fresh = _doc(fig02=_mod(error="RuntimeError: boom"))
    ok, lines = perf_gate.compare(base, fresh)
    assert not ok
    assert any("errored" in l for l in lines)


def test_gate_baseline_error_skips_compare():
    base = _doc(fig02=_mod(error="old failure"))
    fresh = _doc(fig02=_mod(wall=500.0))
    ok, lines = perf_gate.compare(base, fresh)
    assert ok
    assert any("baseline errored" in l for l in lines)


def test_gate_new_and_missing_modules():
    base = _doc(fig02=_mod(), fig06=_mod())
    fresh = _doc(fig02=_mod(), profile=_mod())
    ok, lines = perf_gate.compare(base, fresh)          # subset is fine
    assert ok
    assert any(l.startswith("note profile: new module") for l in lines)
    assert any("not in fresh run" in l for l in lines)
    # but an explicitly requested module must be present
    ok2, lines2 = perf_gate.compare(base, fresh, modules=["fig06"])
    assert not ok2
    assert any("missing from fresh run" in l for l in lines2)


def test_gate_modules_filter_limits_gating():
    base = _doc(fig02=_mod(wall=100.0), fig06=_mod(wall=100.0))
    fresh = _doc(fig02=_mod(wall=100.0), fig06=_mod(wall=900.0))
    ok, _ = perf_gate.compare(base, fresh, modules=["fig02"])
    assert ok                   # fig06's regression is out of scope
    ok2, _ = perf_gate.compare(base, fresh, modules=["fig06"])
    assert not ok2


def test_gate_quick_full_mismatch_skips_wall():
    base = _doc(fig02=_mod(wall=10.0, quick=True))
    fresh = _doc(fig02=_mod(wall=900.0, quick=False))
    ok, lines = perf_gate.compare(base, fresh)
    assert ok
    assert any("mode mismatch" in l for l in lines)


def test_gate_cli_roundtrip(tmp_path):
    base = _doc(fig02=_mod(wall=100.0))
    fresh = _doc(fig02=_mod(wall=400.0))
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    rep = tmp_path / "report.txt"
    rc = perf_gate.main(["--baseline", str(bp), "--fresh", str(fp),
                         "--report", str(rep)])
    assert rc == 1
    assert "gate: FAIL" in rep.read_text()
    rc2 = perf_gate.main(["--baseline", str(bp), "--fresh", str(fp),
                          "--wall-ratio", "10"])
    assert rc2 == 0
    assert perf_gate.main(["--baseline", str(tmp_path / "nope.json"),
                           "--fresh", str(fp)]) == 2


# ----------------------------------------------------- update-baseline ---

def test_speedup_modules_selection_rules():
    base = _doc(fast=_mod(wall=200.0),          # genuine speedup
                slow=_mod(wall=100.0),          # regression
                err=_mod(wall=200.0),           # fresh errored
                olderr=_mod(wall=200.0, error="old"),   # baseline errored
                mode=_mod(wall=200.0, quick=True),      # mode mismatch
                tiny=_mod(wall=9.0))            # inside slack
    fresh = _doc(fast=_mod(wall=20.0),
                 slow=_mod(wall=300.0),
                 err=_mod(wall=20.0, error="boom"),
                 olderr=_mod(wall=20.0),
                 mode=_mod(wall=20.0, quick=False),
                 tiny=_mod(wall=1.0),
                 brandnew=_mod(wall=1.0))       # no baseline entry
    assert perf_gate.speedup_modules(base, fresh) == ["fast"]


def test_speedup_modules_matches_compare_notes():
    # the selection must agree with what compare() flags, or the update
    # rewrites modules the report never mentioned
    base = _doc(a=_mod(wall=200.0), b=_mod(wall=100.0))
    fresh = _doc(a=_mod(wall=20.0), b=_mod(wall=99.0))
    _, lines = perf_gate.compare(base, fresh)
    noted = {l.split()[1].rstrip(":") for l in lines if "speedup" in l}
    assert set(perf_gate.speedup_modules(base, fresh)) == noted == {"a"}


def test_update_baseline_merges_and_resums_wall():
    base = _doc(fast=_mod(wall=200.0, compiles=3),
                keep=_mod(wall=50.0))
    fresh = _doc(fast=_mod(wall=20.0, compiles=5, compile_time_s=1.5),
                 keep=_mod(wall=49.0))
    out = perf_gate.update_baseline(base, fresh, ["fast"])
    assert out["modules"]["fast"]["wall_s"] == 20.0
    assert out["modules"]["fast"]["compiles"] == 5
    assert out["modules"]["fast"]["compile_time_s"] == 1.5
    assert out["modules"]["keep"]["wall_s"] == 50.0      # untouched
    assert out["total_wall_s"] == pytest.approx(70.0)
    # input docs are not mutated
    assert base["modules"]["fast"]["wall_s"] == 200.0


def test_update_baseline_cli_rewrites_only_speedups(tmp_path):
    base = _doc(fast=_mod(wall=200.0), slow=_mod(wall=10.0))
    fresh = _doc(fast=_mod(wall=20.0), slow=_mod(wall=11.0))
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    rc = perf_gate.main(["--baseline", str(bp), "--fresh", str(fp),
                         "--update-baseline"])
    assert rc == 0
    doc = json.loads(bp.read_text())
    assert doc["modules"]["fast"]["wall_s"] == 20.0      # rewritten
    assert doc["modules"]["slow"]["wall_s"] == 10.0      # kept
    assert doc["total_wall_s"] == pytest.approx(30.0)


def test_update_baseline_cli_noop_without_speedups(tmp_path):
    base = _doc(fig02=_mod(wall=100.0))
    fresh = _doc(fig02=_mod(wall=95.0))
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    before = bp.read_text()
    rc = perf_gate.main(["--baseline", str(bp), "--fresh", str(fp),
                         "--update-baseline"])
    assert rc == 0
    assert bp.read_text() == before          # byte-identical: no rewrite


# --------------------------------------------------------------- merge ---

def test_merge_refreshes_one_module_and_resums_wall(tmp_path):
    base = _doc(fig02=_mod(wall=10.0), fig06=_mod(wall=20.0))
    path = tmp_path / "BENCH_run.json"
    path.write_text(json.dumps(base))
    fresh = _doc(fig02=_mod(wall=30.0, compile_time_s=4.5,
                            backend_compiles=7, hlo_kb=12.3,
                            compiled_fns={"jit(_run_dyn)":
                                          {"n": 1, "secs": 4.0}}))
    out, note = merge_only_doc(fresh, str(path))
    assert note is None
    assert set(out["modules"]) == {"fig02", "fig06"}
    assert out["total_wall_s"] == pytest.approx(50.0)
    # the new telemetry fields ride through the merge untouched
    m = out["modules"]["fig02"]
    assert m["compile_time_s"] == 4.5
    assert m["backend_compiles"] == 7
    assert m["compiled_fns"]["jit(_run_dyn)"]["secs"] == 4.0
    # and json-roundtrip cleanly
    m2 = json.loads(json.dumps(out))["modules"]["fig02"]
    assert m2["compiled_fns"]["jit(_run_dyn)"]["n"] == 1


def test_merge_missing_baseline_writes_fresh(tmp_path):
    fresh = _doc(fig02=_mod())
    out, note = merge_only_doc(fresh, str(tmp_path / "absent.json"))
    assert out is fresh and note is None


@pytest.mark.parametrize("content", ["{not json", '{"modules": 17}',
                                     '["a", "list"]'])
def test_merge_corrupt_baseline_is_loud(tmp_path, content):
    path = tmp_path / "corrupt.json"
    path.write_text(content)
    fresh = _doc(fig02=_mod())
    out, note = merge_only_doc(fresh, str(path))
    assert out is fresh
    assert note is not None and note.startswith("merge_skipped=")


def test_top_fns_bounded_and_ranked():
    fns = {f"jit(f{i})": {"n": 1, "secs": float(i)} for i in range(10)}
    top = _top_fns(fns, k=3)
    assert list(top) == ["jit(f9)", "jit(f8)", "jit(f7)"]


def test_parse_row_tolerates_non_numeric():
    rec = _parse_row("roofline_engine_x,1.5,bottleneck=memory;ai=0.62")
    assert rec["us_per_call"] == 1.5
    assert rec["derived"]["bottleneck"] == "memory"
    assert rec["derived"]["ai"] == 0.62


# ------------------------------------------------------------- roofline ---

def _artifact(path, mesh, error=None):
    doc = {"arch": "v5e", "shape": "train", "mesh": mesh}
    if error:
        doc["error"] = error
    else:
        doc["roofline"] = {"bottleneck": "memory", "t_compute_s": 1e-3,
                           "t_memory_s": 2e-3, "t_collective_s": 0.0,
                           "useful_ratio": 0.5, "mfu_bound": 0.4}
        doc["memory"] = {"argument_bytes": 1 << 30, "temp_bytes": 1 << 29}
    path.write_text(json.dumps(doc))


def test_roofline_mesh_filter_applies_to_error_rows(tmp_path):
    _artifact(tmp_path / "a_ok.json", mesh="2x2")
    _artifact(tmp_path / "b_err.json", mesh="2x2", error="OOM")
    _artifact(tmp_path / "c_ok.json", mesh="4x4")
    _artifact(tmp_path / "d_err.json", mesh="4x4", error="OOM")
    allrows = roofline_table.rows(out_dir=str(tmp_path))
    assert len(allrows) == 4
    filtered = roofline_table.rows(mesh_filter="2x2",
                                   out_dir=str(tmp_path))
    assert len(filtered) == 2           # the 4x4 ERROR row is gone too
    assert all(",2x2," in r for r in filtered)
    assert sum("ERROR" in r for r in filtered) == 1
