"""Hypothesis property tests: the §5 correctness invariants of the paper.

The engine models every row's value as a counter (+1 per applied write,
-1 per rollback). At quiescence (drain), for every protocol and workload:

  INVARIANT 1 (serializability / no lost updates): applied == committed
      counts per row — every committed write is applied exactly once and
      every aborted write is fully reverted, across cascades.
  INVARIANT 2 (quiescence): all threads reach HALT; no ticket leaks.
  INVARIANT 3 (commit order == update order): per hot row the commit
      cursor never overtakes an uncommitted earlier update — checked
      implicitly by invariant 1 under cascading aborts (a violated order
      leaves a stale applied increment).
"""
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.lock import (EngineConfig, run_sim, WorkloadSpec, CostModel,
                             protocol_params, HALT)

PROTOS = ["mysql", "o1", "o2", "group", "bamboo", "brook2pl"]


def drain_run(proto, kind, threads, txn_len, p_abort, seed,
              write_ratio=1.0, horizon=60_000):
    cfg = EngineConfig(
        protocol=protocol_params(proto),
        costs=CostModel(),
        workload=WorkloadSpec(kind=kind, txn_len=txn_len, n_rows=256,
                              write_ratio=write_ratio, seed=seed,
                              n_hot=2),
        n_threads=threads,
        horizon=horizon,
        p_abort=p_abort,
        drain=True,
        max_iters=400_000,
        seed=seed,
    )
    return run_sim(cfg)


@settings(max_examples=12, deadline=None)
@given(
    proto=st.sampled_from(PROTOS),
    kind=st.sampled_from(["hotspot_update", "uniform", "fit", "zipf"]),
    threads=st.sampled_from([4, 32, 96]),
    txn_len=st.integers(1, 4),
    p_abort=st.sampled_from([0.0, 0.1]),
    seed=st.integers(0, 10_000),
)
def test_drain_invariants(proto, kind, threads, txn_len, p_abort, seed):
    s = drain_run(proto, kind, threads, txn_len, p_abort, seed)
    # INVARIANT 2: quiesced
    assert bool((s.th.phase == HALT).all()), "threads failed to drain"
    assert bool((s.th.ticket < 0).all()), "ticket leak"
    # INVARIANT 1: serializability of the counter values
    leftover = int(jnp.abs(s.rows.applied_val - s.rows.committed_val).sum())
    assert leftover == 0, f"lost/dirty updates: {leftover}"
    # sanity: work actually happened
    assert int(s.g.commits) > 0


@settings(max_examples=6, deadline=None)
@given(
    threads=st.sampled_from([48, 80]),
    seed=st.integers(0, 1000),
)
def test_cascade_reverts_completely(threads, seed):
    """Inject aborts under group locking: cascades must fully revert."""
    s = drain_run("group", "hotspot_update", threads, 1, 0.3, seed)
    leftover = int(jnp.abs(s.rows.applied_val - s.rows.committed_val).sum())
    assert leftover == 0
    assert int(s.g.forced_aborts) > 0    # cascades actually exercised


@settings(max_examples=6, deadline=None)
@given(
    proto=st.sampled_from(["group", "bamboo"]),
    seed=st.integers(0, 1000),
)
def test_hot_nonhot_mix_no_deadlock_livelock(proto, seed):
    """FiT-like hot+non-hot transactions (§4.5's deadlock scenario) must
    drain — via proactive rollback (group) or detection (bamboo)."""
    s = drain_run(proto, "fit", 64, 2, 0.0, seed, horizon=50_000)
    assert bool((s.th.phase == HALT).all())
    leftover = int(jnp.abs(s.rows.applied_val - s.rows.committed_val).sum())
    assert leftover == 0


@settings(max_examples=14, deadline=None)
@given(
    kind=st.sampled_from(["zipf", "tpcc", "hotspot_update"]),
    threads=st.sampled_from([4, 32, 96]),
    txn_len=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_brook2pl_deadlock_free(kind, threads, txn_len, seed):
    """Brook-2PL's structural claim: with no injected aborts, chop-ordered
    acquisition admits NO rollback of any kind — no deadlock victims (no
    cycles can form), no timeouts (they're disabled because no wait can
    be indefinite), no cascades (nothing ever aborts) — while the system
    drains and the serializability counter invariant holds. This is
    strictly stronger than the generic drain invariants: every dynamic-
    resolution protocol pays aborts on these workloads at high skew."""
    s = drain_run("brook2pl", kind, threads, txn_len, 0.0, seed)
    assert bool((s.th.phase == HALT).all()), "brook2pl failed to drain"
    assert bool((s.th.ticket < 0).all()), "ticket leak"
    assert int(s.g.forced_aborts) == 0, "deadlock/cascade rollback"
    assert int(s.g.user_aborts) == 0
    assert int(s.g.dd_ticks) == 0, "paid deadlock-detection ticks"
    leftover = int(jnp.abs(s.rows.applied_val - s.rows.committed_val).sum())
    assert leftover == 0, f"lost/dirty updates: {leftover}"
    assert int(s.g.commits) > 0


@settings(max_examples=8, deadline=None)
@given(
    kind=st.sampled_from(["zipf", "tpcc", "hotspot_update"]),
    seed=st.integers(0, 1000),
)
def test_brook2pl_injected_aborts_never_cascade(kind, seed):
    """Injected commit-point aborts under brook2pl stay singular: a txn
    that will abort keeps strict-2PL holds (per-op release is gated on
    ~willab), so no successor ever reads its writes and forced/cascade
    aborts stay at zero even at p_abort=0.3."""
    s = drain_run("brook2pl", kind, 48, 3, 0.3, seed)
    assert bool((s.th.phase == HALT).all())
    assert int(s.g.user_aborts) > 0      # injection actually exercised
    assert int(s.g.forced_aborts) == 0, "a brook abort cascaded"
    leftover = int(jnp.abs(s.rows.applied_val - s.rows.committed_val).sum())
    assert leftover == 0
