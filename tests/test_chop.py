"""Unit tests for the transaction-chopping / SLW-order analysis
(``repro.core.lock.chop``): the acquisition order is a total order over
the key space for every workload kind, release points are last-use,
tpcc templates chop into the expected class structure, and the traced
helpers (op re-sort, per-instance last-use) behave under padding."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.lock import WorkloadSpec, chop
from repro.core.lock.workload import dyn_workload, gen_txn_dyn

I32 = jnp.int32

KINDS = ["hotspot_update", "hotspot_mix", "hotspot_scan", "uniform",
         "zipf", "fit", "tpcc"]


def spec(kind, **kw):
    base = dict(kind=kind, n_rows=64, txn_len=4, write_ratio=0.6,
                n_hot=2, n_warehouses=2)
    base.update(kw)
    return WorkloadSpec(**base)


class TestAcquisitionOrder:
    @pytest.mark.parametrize("kind", KINDS)
    def test_rank_is_total_order(self, kind):
        """The per-key rank must be a permutation of [0, R): a TOTAL
        order — any ties would let two transactions acquire a tied pair
        in opposite orders and re-admit waits-for cycles."""
        r = np.asarray(chop.acquisition_rank(spec(kind)))
        assert r.dtype == np.int32
        assert sorted(r.tolist()) == list(range(64))

    def test_hot_keys_rank_last(self):
        """SLW ordering: the hottest class is acquired LAST (shortest
        hold). zipf key 0 is the hottest; tpcc warehouses beat districts
        beat stock; hotspot_update's single hot row tops everything."""
        rz = np.asarray(chop.acquisition_rank(spec("zipf", zipf_s=0.9)))
        assert rz[0] == 63 and rz[1] == 62        # pmf-descending keys
        rh = np.asarray(chop.acquisition_rank(spec("hotspot_update")))
        assert rh[0] == 63
        rt = np.asarray(chop.acquisition_rank(spec("tpcc")))
        wh, dist, stock = rt[:2], rt[2:22], rt[22:]
        assert wh.min() > dist.max() > stock.max()

    def test_fit_rotation_only_moves_the_hot_window(self):
        """fit's record inserts draw UNROTATED from [n_hot, R); only the
        hot-account window follows hot_base (mirrors gen_txn_dyn). The
        migrated window must rank last wherever it lands, and the vacated
        original window (now never accessed) coldest."""
        s = spec("fit", n_rows=64, n_hot=4, hot_base=16)
        r = np.asarray(chop.acquisition_rank(s))
        assert set(r[16:20]) == {60, 61, 62, 63}    # migrated hot window
        assert set(r[0:4]) == {0, 1, 2, 3}          # vacated: heat 0

    def test_rank_follows_hot_base_rotation(self):
        """Drift schedules relocate the hot set; the rank table must
        follow it (it ships per-segment like the Zipf CDF)."""
        r0 = np.asarray(chop.acquisition_rank(spec("zipf", zipf_s=0.9)))
        r7 = np.asarray(chop.acquisition_rank(
            spec("zipf", zipf_s=0.9, hot_base=7)))
        assert r7[7] == 63 and (np.roll(r7, -7) == r0).all()

    @pytest.mark.parametrize("kind", KINDS)
    def test_class_order_ascends_in_heat(self, kind):
        plan = chop.chop(spec(kind))
        heats = {c.name: c.heat for c in plan.classes}
        seq = [heats[n] for n in plan.order]
        assert seq == sorted(seq)
        assert set(plan.order) == set(heats)


class TestReleasePoints:
    def test_template_release_is_last_use(self):
        """Static release point of a slot == last slot of its class."""
        rel = chop.template_release_points(spec("tpcc", txn_len=6))
        assert rel == [0, 1, 5, 5, 5, 5]      # wh, dist, stock x4
        rel1 = chop.template_release_points(spec("hotspot_update"))
        assert rel1 == [0, 3, 3, 3]           # hot row frees instantly
        for kind in KINDS:
            tmpl = chop.txn_template(spec(kind))
            for t, r in zip(tmpl, chop.template_release_points(spec(kind))):
                assert r >= t.slot            # release never precedes use

    def test_last_use_exact_per_instance(self):
        keys = jnp.asarray([[3, 5, 3, 9],
                            [1, 1, 1, 7]], I32)
        nops = jnp.asarray([4, 3], I32)       # lane 1: slot 3 padded
        lu = np.asarray(chop.last_use(keys, nops))
        assert lu.tolist() == [[False, True, True, True],
                               [False, False, True, False]]


class TestTpccChop:
    def test_tpcc_template_classes(self):
        tmpl = chop.txn_template(spec("tpcc", txn_len=5))
        assert [t.cls for t in tmpl] == \
            ["warehouse", "district", "stock", "stock", "stock"]
        assert tmpl[0].wr and tmpl[1].wr      # structural writes
        plan = chop.chop(spec("tpcc", txn_len=5))
        assert plan.order == ("stock", "district", "warehouse")
        # the heaviest SLW edges originate at the warehouse lock: program
        # order holds the hottest class across every later wait pre-chop
        # — exactly what acquiring it last eliminates
        assert plan.slw[0][0] == "warehouse"
        assert {e[:2] for e in plan.slw} == {
            ("warehouse", "district"), ("warehouse", "stock"),
            ("district", "stock")}

    def test_generated_tpcc_txns_acquire_in_rank_order(self):
        """End-to-end: gen_txn_dyn under ordered_acquire emits programs
        whose active slots ascend in rank — warehouse last."""
        s = spec("tpcc", n_rows=256, txn_len=6, n_warehouses=2)
        dw = dyn_workload(s)
        tids = jnp.arange(8, dtype=I32)
        ctr = jnp.zeros(8, I32)
        keys, iswr, dup, lastu, nops = gen_txn_dyn(
            "tpcc", 256, 6, dw, tids, ctr,
            acq_order=jnp.asarray(True))
        # the inlined lastu (shares dup's eq tensor) == chop.last_use
        assert (np.asarray(lastu)
                == np.asarray(chop.last_use(keys, nops))).all()
        rank = np.asarray(dw.acq_rank)
        k = np.asarray(keys)
        for t in range(8):
            rr = rank[k[t, :int(nops[t])]]
            # non-decreasing; equal ranks are the same key (re-entrant)
            assert (np.diff(rr) >= 0).all(), (t, rr)
        # warehouse (keys 0..1) sits in the LAST active slot
        assert (k[:, 5] <= 1).all()

    def test_disabled_order_is_identity(self):
        s = spec("zipf", n_rows=128, zipf_s=0.9)
        dw = dyn_workload(s)
        tids = jnp.arange(16, dtype=I32)
        ctr = jnp.full(16, 3, I32)
        plain = gen_txn_dyn("zipf", 128, 4, dw, tids, ctr)
        off = gen_txn_dyn("zipf", 128, 4, dw, tids, ctr,
                          acq_order=jnp.asarray(False))
        for a, b in zip(plain, off):
            assert (np.asarray(a) == np.asarray(b)).all()

    def test_padded_slots_stay_out_of_active_range(self):
        """L=6 program, txn_len=3: the sort must keep the 3 padded slots
        after every active one (padding stays bitwise invisible)."""
        s = spec("zipf", n_rows=128, zipf_s=0.9, txn_len=3)
        dw = dyn_workload(s)
        tids = jnp.arange(8, dtype=I32)
        ctr = jnp.zeros(8, I32)
        keys6, iswr6, _, _, _ = gen_txn_dyn("zipf", 128, 6, dw, tids, ctr,
                                            acq_order=jnp.asarray(True))
        keys3, iswr3, _, _, _ = gen_txn_dyn("zipf", 128, 3, dw, tids, ctr,
                                            acq_order=jnp.asarray(True))
        assert (np.asarray(keys6)[:, :3] == np.asarray(keys3)).all()
        assert (np.asarray(iswr6)[:, :3] == np.asarray(iswr3)).all()


class TestPlan:
    @pytest.mark.parametrize("kind", KINDS)
    def test_plan_describe_roundtrips(self, kind):
        plan = chop.chop(spec(kind))
        text = plan.describe()
        assert kind in text and "acquire order" in text
        for name in plan.order:
            assert name in text

    def test_unknown_kind_raises(self):
        bogus = dataclasses.replace(spec("zipf"))
        object.__setattr__(bogus, "kind", "nosuch")
        with pytest.raises(ValueError, match="nosuch"):
            chop.row_classes(bogus)
