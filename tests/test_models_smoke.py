"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward + one train step on CPU, asserting shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, ARCHS, SHAPES, input_specs
from repro.models import lm_spec, init_params, forward, loss_fn
from repro.optim import adamw
from repro.launch.steps import make_train_step

B, S = 2, 32


def make_batch(cfg, key):
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        batch["embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16)
    if cfg.n_codebooks:
        batch["labels"] = jax.random.randint(
            key, (B, S, cfg.n_codebooks), 0, cfg.vocab)
    else:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.mrope:
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(lm_spec(cfg), key)
    batch = make_batch(cfg, key)
    out = forward(params, cfg,
                  tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                  positions3=batch.get("positions3"), mode="train")
    K = max(cfg.n_codebooks, 1)
    want = (B, S, cfg.padded_vocab) if K == 1 else \
        (B, S, K, cfg.padded_vocab)
    assert out.logits.shape == want
    assert bool(jnp.isfinite(out.logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = init_params(lm_spec(cfg), key)
    opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=1,
                                decay_steps=100)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = make_batch(cfg, key)       # fixed batch: loss must drop
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert jnp.isfinite(metrics["loss"]), arch
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    from repro.configs import shape_grid
    for shape in shape_grid(arch):
        specs = input_specs(cfg, shape)
        leaves = jax.tree.leaves(specs)
        assert leaves, (arch, shape.name)
        for leaf in leaves:
            assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
