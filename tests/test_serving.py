"""Serving-layer tests: differential parity against the closed loop,
analytic M/M/c validation (Thomasian, arXiv:2404.02276), open-system
invariants (property tests), compile discipline, and the first coverage
for the dormant LM-decode GroupServer shell.

Parity standard (same bar test_sweep.py holds the sweep substrate to):
with a saturating schedule and unbinding credit quotas, the serving path
IS the segmented closed loop — every state leaf must match a single-shot
run of the same padded config bit-for-bit, except the diagnostic
``Globals.iters``, which a segment boundary may legitimately split
(0 <= open - ref <= n_segments - 1, the run_segment contract).
"""
import os

import numpy as np
import pytest
import jax

from repro.core.lock import engine as E
from repro.core.lock import (CostModel, WorkloadSpec, extract, simulate,
                             protocol_params)
from repro.serving import (ArrivalSchedule, ServeCell, bursty, flash_crowd,
                           poisson, predicted_response_ticks,
                           predicted_util, saturating, serve, service_ticks,
                           uniform)

SEED = 11


# ---------------------------------------------------------------------------
# arrival schedules
# ---------------------------------------------------------------------------

class TestArrivals:
    def test_poisson_rate_and_determinism(self):
        a = poisson(0.01, 400_000, seed=SEED)
        b = poisson(0.01, 400_000, seed=SEED)
        assert np.array_equal(a.times, b.times)     # seeded => bit-stable
        assert a.times.dtype == np.int64
        assert (np.diff(a.times) >= 0).all()
        assert 0 <= a.times[0] and a.times[-1] < 400_000
        # ~4000 expected arrivals; Poisson sd ~63 — 5 sigma
        assert abs(a.n - 4000) < 320
        assert a.offered_tps == pytest.approx(a.n * 1e7 / 400_000)

    def test_bursty_and_flash_crowd_modulate(self):
        b = bursty(0.001, 0.02, 400_000, period=100_000, duty=0.25,
                   seed=SEED)
        in_burst = (b.times % 100_000) < 25_000
        # burst quarters carry ~20x the base rate
        assert in_burst.sum() > 3 * (~in_burst).sum()
        f = flash_crowd(0.001, 0.02, 400_000, at=0.5, spike_frac=0.25,
                        seed=SEED)
        spike = (f.times >= 200_000) & (f.times < 300_000)
        assert spike.sum() > 2 * (~spike).sum()

    def test_uniform_and_saturating(self):
        u = uniform(0.001, 100_000)
        assert u.n == 100 and np.diff(u.times).min() == 1000
        s = saturating(500, 100_000)
        assert s.n == 500 and s.times.max() == 0

    def test_schedule_validation(self):
        with pytest.raises(AssertionError):
            ArrivalSchedule("bad", np.array([5, 3]), 10)
        with pytest.raises(AssertionError):
            ArrivalSchedule("bad", np.array([3, 50]), 10)


# ---------------------------------------------------------------------------
# differential parity: open system == closed loop when saturated
# ---------------------------------------------------------------------------

W_PARITY = WorkloadSpec(kind="zipf", txn_len=4, n_rows=1024, zipf_s=0.9)
T_PARITY, H_PARITY, SEG_PARITY = 8, 120_000, 20_000


def _closed_loop_state(preset: str, pad_t: int):
    """Single-shot reference at the serving layer's padded shape."""
    cfg = E.EngineConfig(protocol=protocol_params(preset),
                         costs=CostModel(), workload=W_PARITY,
                         n_threads=T_PARITY, horizon=H_PARITY)
    stat, dp = E.split_config(cfg, pad_threads=pad_t)
    return E._run_dyn(stat, dp, E.init_state_dyn(stat, dp))


class TestSaturatingParity:
    @pytest.fixture(scope="class")
    def served(self):
        # enough requests that the queue outlives the horizon; per-slot
        # credit high enough that the quota never binds => the device
        # must replay the closed loop exactly
        sched = saturating(30_000, H_PARITY)
        cells = [ServeCell(name=p, schedule=sched, workload=W_PARITY,
                           n_threads=T_PARITY, preset=p, admission="wait",
                           max_outstanding=30_000)
                 for p in ("mysql", "group")]
        return serve(cells, seg_ticks=SEG_PARITY, return_states=True)

    @pytest.mark.parametrize("preset", ["mysql", "group"])
    def test_every_state_leaf_bitexact(self, served, preset):
        n_seg = H_PARITY // SEG_PARITY
        s_open = served.states[preset]
        s_ref = _closed_loop_state(preset, 64)
        paths = [jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(s_ref)[0]]
        o = jax.device_get(jax.tree.leaves(s_open))
        r = jax.device_get(jax.tree.leaves(s_ref))
        for path, a, b in zip(paths, o, r):
            if path.endswith(".iters"):
                d = int(a) - int(b)
                assert 0 <= d <= n_seg - 1, (path, d)
            else:
                assert np.array_equal(a, b), path

    @pytest.mark.parametrize("preset", ["mysql", "group"])
    def test_metrics_match_simulate(self, served, preset):
        """Extracted metrics equal plain simulate()'s, field for field
        (iters excepted per the segment contract)."""
        ref = extract(preset, T_PARITY,
                      simulate(preset, W_PARITY, T_PARITY,
                               horizon=H_PARITY))
        got = served.metrics[preset]
        for f in ("commits", "user_aborts", "forced_aborts", "lock_ops",
                  "dd_ticks", "tps", "mean_latency_us", "p95_latency_us",
                  "abort_rate", "lock_wait_frac", "cpu_util"):
            assert getattr(got, f) == getattr(ref, f), (preset, f)
        assert 0 <= got.iters - ref.iters <= H_PARITY // SEG_PARITY - 1

    def test_serving_counts_match_engine(self, served):
        """Responses are txn completions: completed == commits (p_abort=0)
        and the quota never rejected or queued out anything."""
        for p in ("mysql", "group"):
            s = served.serving[p]
            assert s.completed == served.metrics[p].commits
            assert s.rejected == 0 and s.shed == 0
            assert s.arrived == 30_000
            assert s.completed + s.in_flight_end + s.qlen_end == 30_000

    def test_single_compile_for_both_protocols(self, served):
        assert served.n_compiles <= 1


class TestCompileDiscipline:
    def test_second_run_compiles_nothing(self):
        """Repeated serving runs (fresh schedules, same shapes) must hit
        the segment executable cache — the acceptance criterion."""
        def run(seed):
            cells = [ServeCell(name=f"c{seed}", workload=W_PARITY,
                               schedule=poisson(0.003, 60_000, seed=seed),
                               n_threads=T_PARITY, preset="mysql",
                               max_outstanding=64, admission="wait")]
            return serve(cells, seg_ticks=15_000)
        run(1)                          # warm (may compile)
        res2 = run(2)
        assert res2.n_compiles == 0


# ---------------------------------------------------------------------------
# analytic validation (Thomasian M/M/c, low contention)
# ---------------------------------------------------------------------------

W_MMC = WorkloadSpec(kind="uniform", txn_len=4, n_rows=65_536,
                     write_ratio=0.5)
T_MMC, H_MMC, SEG_MMC = 8, 120_000, 500
TOL = 0.15


def _mmc_measure(rhos):
    costs = CostModel()
    cap = T_MMC / service_ticks(W_MMC, costs, "mysql")  # arrivals/tick
    cells = [ServeCell(name=f"rho{r}", workload=W_MMC, n_threads=T_MMC,
                       schedule=poisson(r * cap, H_MMC, seed=7),
                       preset="mysql", admission="wait",
                       max_outstanding=1_000)
             for r in rhos]
    res = serve(cells, seg_ticks=SEG_MMC, chunk_size=len(cells))
    out = []
    for r in rhos:
        s = res.serving[f"rho{r}"]
        # the boundary quantization correction (DESIGN.md §10): dispatch
        # waits mean seg/2 after arrival, observation rounds up mean
        # seg/2 after completion
        pred = predicted_response_ticks(r * cap, W_MMC, costs,
                                        T_MMC, "mysql") + SEG_MMC
        pred_u = predicted_util(r * cap, W_MMC, costs, T_MMC, "mysql")
        out.append((r, s.mean_resp_us * 10.0, pred, s.utilization, pred_u,
                    s.completed))
    return out


class TestAnalyticValidation:
    def test_mmc_below_knee(self):
        """Measured mean response and utilization within ±15% of the
        M/M/c prediction at 3 offered loads below the knee."""
        rows = _mmc_measure((0.2, 0.4, 0.6))
        for rho, meas, pred, util, pred_u, n in rows:
            assert n > 300, (rho, n)    # enough completions to average
            assert meas == pytest.approx(pred, rel=TOL), (rho, meas, pred)
            assert util == pytest.approx(pred_u, rel=TOL), (rho, util)

    @pytest.mark.skipif(not os.environ.get("REPRO_SERVING_FULL"),
                        reason="full analytic curve: REPRO_SERVING_FULL=1")
    def test_mmc_full_curve(self):
        rows = _mmc_measure((0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8))
        for rho, meas, pred, util, pred_u, _ in rows:
            assert meas == pytest.approx(pred, rel=TOL), (rho, meas, pred)
            assert util == pytest.approx(pred_u, rel=TOL), (rho, util)


# ---------------------------------------------------------------------------
# admission control semantics
# ---------------------------------------------------------------------------

W_SMALL = WorkloadSpec(kind="uniform", txn_len=2, n_rows=512,
                       write_ratio=1.0)


def _overloaded(admission, cap=8):
    cells = [ServeCell(name="x", schedule=saturating(2_000, 20_000),
                       workload=W_SMALL, n_threads=4, preset="o2",
                       queue_cap=cap, admission=admission,
                       max_outstanding=2)]
    return serve(cells, seg_ticks=5_000).serving["x"]


class TestAdmission:
    def test_reject_drops_newcomers(self):
        s = _overloaded("reject")
        assert s.rejected > 0 and s.shed == 0
        assert s.qlen_end <= 8

    def test_shed_drops_oldest(self):
        s = _overloaded("shed")
        assert s.shed > 0 and s.rejected == 0
        assert s.qlen_end <= 8

    def test_wait_is_unbounded(self):
        s = _overloaded("wait")
        assert s.rejected == 0 and s.shed == 0
        assert s.qlen_end > 8                   # cap ignored
        # conservation still holds
        assert s.arrived == s.completed + s.in_flight_end + s.qlen_end


# ---------------------------------------------------------------------------
# property tests (hypothesis)
# ---------------------------------------------------------------------------

class TestProperties:
    """Open-system invariants over drawn schedules and admission knobs."""

    @pytest.fixture(autouse=True)
    def _hyp(self):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (requirements-dev)")

    def test_conservation_and_queue_bound_at_every_boundary(self):
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=8, deadline=None)
        @given(seed=st.integers(0, 2**16), rate=st.floats(0.001, 0.05),
               cap=st.integers(2, 32),
               admission=st.sampled_from(["reject", "shed"]),
               mo=st.integers(1, 8))
        def prop(seed, rate, cap, admission, mo):
            cells = [ServeCell(name="p", workload=W_SMALL, n_threads=4,
                               schedule=poisson(rate, 20_000, seed=seed),
                               preset="o2", queue_cap=cap,
                               admission=admission, max_outstanding=mo)]
            res = serve(cells, seg_ticks=5_000)
            cum_arr = cum_rej = cum_shed = cum_done = 0
            for rec in res.segments["p"]:
                cum_arr += rec["arrived"]
                cum_rej += rec["rejected"]
                cum_shed += rec["shed"]
                cum_done += rec["completed"]
                # queue length never exceeds the backpressure cap
                assert rec["qlen"] <= cap
                # admitted = completed + rejected(+shed) + queued +
                # in-flight, at EVERY boundary
                assert cum_arr == (cum_rej + cum_shed + cum_done
                                   + rec["qlen"] + rec["in_flight"])
            s = res.serving["p"]
            assert (cum_arr, cum_rej, cum_shed, cum_done) == (
                s.arrived, s.rejected, s.shed, s.completed)

        prop()

    def test_percentile_ordering_and_load_monotonicity(self):
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=4, deadline=None)
        @given(seed=st.integers(0, 2**16))
        def prop(seed):
            # fixed protocol, rising offered load across the knee
            cap = 4 / service_ticks(W_SMALL, CostModel(), "o2")
            cells = [ServeCell(name=f"l{i}", workload=W_SMALL,
                               n_threads=4, preset="o2", admission="wait",
                               schedule=poisson(f * cap, 40_000,
                                                seed=seed),
                               max_outstanding=50)
                     for i, f in enumerate((0.3, 1.0, 3.0))]
            res = serve(cells, seg_ticks=8_000)
            means = []
            for i in range(3):
                s = res.serving[f"l{i}"]
                assert s.p50_us <= s.p99_us <= s.p999_us <= s.max_us
                means.append(s.mean_resp_us)
            # latencies monotone non-decreasing in offered load
            assert means[0] <= means[1] <= means[2]

        prop()


# ---------------------------------------------------------------------------
# device-histogram percentiles vs host response lists (obs layer)
# ---------------------------------------------------------------------------

class TestDevicePercentiles:
    """ServingResult p50/p99/p999 now come from the engine's log-bucket
    response histogram, not a host-side list. ``keep_responses=True``
    retains the old per-request list purely so this test can check the
    two agree to within the histogram's bucket resolution."""

    def test_hist_percentiles_match_host_responses(self):
        # ~70% of M/M/c capacity on the contention-free workload: busy
        # enough for a wide queueing-delay spread, light enough that most
        # arrivals complete inside the horizon (a contended workload here
        # would collapse and leave too few samples for p999)
        rate = 0.7 * 8 / service_ticks(W_MMC, CostModel(), "o2")
        sched = poisson(rate, 120_000, seed=SEED)
        cells = [ServeCell(name="x", schedule=sched, workload=W_MMC,
                           n_threads=8, preset="o2", admission="wait",
                           max_outstanding=5_000)]
        res = serve(cells, seg_ticks=20_000, keep_responses=True)
        s = res.serving["x"]
        rs = np.sort(np.asarray(res.responses["x"]))
        assert len(rs) == s.completed > 100
        assert s.max_us == pytest.approx(rs[-1])
        # log buckets are base-1.3 wide and report the geometric
        # midpoint, so the device estimate sits within ~sqrt(1.3) of the
        # exact order statistic (inverted CDF), plus the -1 tick offset
        # of the smallest buckets
        for q, got in ((0.50, s.p50_us), (0.99, s.p99_us),
                       (0.999, s.p999_us)):
            k = min(int(np.ceil(q * len(rs))) - 1, len(rs) - 1)
            want = rs[max(k, 0)]
            assert want / 1.35 - 0.5 <= got <= want * 1.35 + 0.5, (
                q, got, want)

    def test_keep_responses_off_by_default(self):
        rate = 0.5 * 4 / service_ticks(W_MMC, CostModel(), "o2")
        cells = [ServeCell(name="x", schedule=poisson(rate, 30_000,
                                                      seed=SEED),
                           workload=W_MMC, n_threads=4, preset="o2",
                           admission="wait", max_outstanding=500)]
        res = serve(cells, seg_ticks=10_000)
        assert res.responses == {}


# ---------------------------------------------------------------------------
# governed serving
# ---------------------------------------------------------------------------

class TestGovernedServing:
    def test_policy_switches_under_open_load(self):
        from repro.adaptive import QueueRulePolicy
        hot = WorkloadSpec(kind="hotspot_update", txn_len=2, n_rows=2048)
        cells = [ServeCell(name="gov", schedule=saturating(4_000, 60_000),
                           workload=hot, n_threads=32, preset="o2",
                           policy=QueueRulePolicy(), admission="wait",
                           max_outstanding=200)]
        res = serve(cells, seg_ticks=10_000)
        presets = [r["preset"] for r in res.segments["gov"]]
        # the rule must promote the saturated hotspot to group locking
        assert "group" in presets
        s = res.serving["gov"]
        assert s.completed == res.metrics["gov"].commits

    def test_resolver_free_switch_rejected(self):
        from repro.adaptive.governor import Policy

        class BadPolicy(Policy):
            name = "bad"

            def decide(self, k, history):
                return "mysql" if k == 0 else "brook2pl"

        cells = [ServeCell(name="bad", workload=W_SMALL, n_threads=4,
                           schedule=saturating(500, 20_000),
                           preset="mysql", policy=BadPolicy(),
                           admission="wait", max_outstanding=200)]
        with pytest.raises(ValueError, match="resolver-free"):
            serve(cells, seg_ticks=5_000)


# ---------------------------------------------------------------------------
# the dormant LM-decode GroupServer (launch/serve.py)
# ---------------------------------------------------------------------------

class TestGroupServerSmoke:
    def test_serve_demo_invariants(self):
        from repro.launch.serve import serve_demo
        srv = serve_demo(n_requests=4, batch_slots=2)
        # every request ran to completion and left its slot
        assert all(r is None for r in srv.active)
        assert not srv.queue
        # max_new = 4 + rid % 5 for rid in 0..3 => 4+5+6+7 tokens total
        assert srv.members_served == 22
        # a step serves at most batch_slots members, at least one
        assert srv.steps_fired >= 11        # ceil(22 / 2 slots)
        assert srv.steps_fired <= 22
        eff = srv.members_served / srv.steps_fired
        assert 1.0 <= eff <= 2.0
