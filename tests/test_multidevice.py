"""Multi-device integration via subprocess (forced host devices): actually
EXECUTES a sharded train step (FSDP+TP+SP) and a sharded decode step on a
data x model mesh — the same code paths the 512-device dry-run lowers.

Default is a smoke-size run (2x2 mesh, one train step) so tier-1 stays
fast on small hosts; set ``REPRO_MULTIDEVICE_FULL=1`` for the original
4x2/8-device two-step version. The subprocesses pin ``JAX_PLATFORMS=cpu``
(forced host devices live on the CPU backend anyway): letting jax probe
for accelerator plugins cost ~8 min of backend-discovery timeouts *per
subprocess* on this image — that, not the compute, was the historical
">9 min on a 2-core host"."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

FULL = os.environ.get("REPRO_MULTIDEVICE_FULL") == "1"
N_DEV, MESH, N_STEPS = (8, "(4, 2)", 2) if FULL else (4, "(2, 2)", 1)


def run_sub(code: str, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    return out.stdout


HEADER = (
    "import os;"
    f"os.environ['XLA_FLAGS']="
    f"'--xla_force_host_platform_device_count={N_DEV}';"
    "import jax, jax.numpy as jnp, numpy as np, dataclasses;"
    "from repro.configs import get_config;"
    "from repro.models import lm_spec, init_params;"
    "from repro.optim import adamw;"
    "from repro.distributed import param_shardings, batch_shardings;"
    "from repro.distributed.sharding import set_activation_mesh;"
    "from repro.launch.steps import make_train_step;"
    f"mesh = jax.make_mesh({MESH}, ('data', 'model'));"
)


def test_sharded_train_step_executes():
    code = HEADER + (
        "cfg = dataclasses.replace(get_config('qwen2-0.5b', smoke=True),"
        " d_model=64, loss_chunk=16, attn_chunk=16);"
        "specs = lm_spec(cfg);"
        "set_activation_mesh(mesh);\n"
        "with mesh:\n"
        "  p_shard = param_shardings(specs, mesh, 'train');\n"
        "  params = jax.jit(lambda k: init_params(lm_spec(cfg), k),"
        " out_shardings=p_shard)(jax.random.PRNGKey(0));\n"
        "  opt = adamw.init(params);\n"
        "  batch = {'tokens': jnp.zeros((8, 64), jnp.int32),"
        " 'labels': jnp.ones((8, 64), jnp.int32)};\n"
        "  step = jax.jit(make_train_step(cfg, adamw.AdamWConfig()));\n"
        f"  for _ in range({N_STEPS}):\n"
        "    params, opt, m = step(params, opt, batch);\n"
        "  assert np.isfinite(float(m['loss'])), m;\n"
        "  print('ok', float(m['loss']))\n"
    )
    out = run_sub(code)
    assert "ok" in out


def test_sharded_decode_executes():
    code = HEADER + (
        "from repro.models import prefill, decode_step;"
        "from repro.models.transformer import lm_init_cache;"
        "cfg = get_config('gemma3-12b', smoke=True);"
        "params = init_params(lm_spec(cfg), jax.random.PRNGKey(0));"
        "set_activation_mesh(mesh);\n"
        "with mesh:\n"
        "  toks = jnp.zeros((8, 24), jnp.int32);\n"
        "  _, caches = prefill(params, cfg, tokens=toks, max_len=32);\n"
        "  lg, caches = decode_step(params, cfg,"
        " tokens=jnp.ones((8, 1), jnp.int32), caches=caches,"
        " pos=jnp.asarray(24, jnp.int32));\n"
        "  assert np.isfinite(np.asarray(lg, np.float32)).all();\n"
        "  print('ok')\n"
    )
    out = run_sub(code)
    assert "ok" in out
