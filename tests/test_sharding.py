"""Sharding resolver tests (AbstractMesh — no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, SHAPES, input_specs
from repro.models import lm_spec
from repro.models.transformer import lm_cache_shapes
from repro.distributed.sharding import (RULES, resolve_spec, param_pspecs,
                                        ResolveReport, _cache_leaf_pspec,
                                        cache_shardings)

def _abstract_mesh(shape, names):
    try:                        # jax >= 0.4.38: (axis_sizes, axis_names)
        return AbstractMesh(shape, names)
    except TypeError:           # jax 0.4.37: ((name, size), ...) pairs
        return AbstractMesh(tuple(zip(names, shape)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class TestResolver:
    def test_fsdp_tp_two_axes(self):
        s = resolve_spec((7168, 19200), ("embed", "mlp"), MESH,
                         RULES["train"])
        assert s == P("data", "model")

    def test_vocab_two_axis_when_divisible(self):
        s = resolve_spec((32256, 7168), ("vocab", "embed"), MESH,
                         RULES["train"])
        assert s[0] == ("data", "model")

    def test_divisibility_fallback(self):
        rep = ResolveReport()
        # 151936 % 256 != 0 -> falls to single-axis sharding
        s = resolve_spec((151936, 896), ("vocab", "embed"), MESH,
                         RULES["train"], rep)
        assert s[0] == "model"

    def test_no_axis_reuse_within_tensor(self):
        s = resolve_spec((128, 7168, 4864), ("experts", "embed", "mlp"),
                         MESH, RULES["train"])
        used = [a for a in jax.tree.leaves(tuple(s)) if a]
        assert len(set(used)) == len(used)

    def test_replicate_when_nothing_fits(self):
        s = resolve_spec((7,), ("heads",), MESH, RULES["train"])
        assert s == P(None)

    def test_serve_rules_keep_weights_off_data_axis(self):
        # dense mlp: model only; embed: replicated (no per-step gathers)
        s = resolve_spec((896, 4864), ("embed", "mlp"), MESH,
                         RULES["serve"])
        assert s == P(None, "model")

    def test_serve_expert_ff_spills_to_data(self):
        # arctic-480b: experts on model, ff on data => weights fit a pod
        s = resolve_spec((128, 7168, 4864), ("experts", "embed", "mlp"),
                         MESH, RULES["serve"])
        assert s[0] == "model" and s[2] == "data"

    @pytest.mark.parametrize("arch", ["deepseek-coder-33b", "arctic-480b",
                                      "mamba2-1.3b"])
    @pytest.mark.parametrize("mesh", [MESH, MESH3])
    def test_full_trees_resolve(self, arch, mesh):
        cfg = get_config(arch)
        tree = param_pspecs(lm_spec(cfg), mesh, "train")
        for ps in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P)):
            assert isinstance(ps, P)


class TestCacheShardings:
    def test_kv_heads_preferred_when_divisible(self):
        # 16 kv heads % 16 == 0 -> heads axis
        s = _cache_leaf_pspec(MESH, "k", (27, 128, 32768, 16, 128), True)
        assert s[3] == "model" and s[1] in ("data", ("data",))

    def test_seq_fallback_when_heads_indivisible(self):
        s = _cache_leaf_pspec(MESH, "k", (62, 128, 32768, 8, 128), True)
        assert s[2] == "model"        # 8 kv heads % 16 != 0 -> shard seq

    def test_head_dim_never_sharded(self):
        s = _cache_leaf_pspec(MESH, "k", (2, 128, 100, 3, 128), True)
        assert s[4] is None

    def test_batch_one_replicates(self):
        s = _cache_leaf_pspec(MESH, "k", (48, 1, 524288, 8, 240), True)
        assert s[1] is None and s[2] == "model"

    @pytest.mark.parametrize("arch", ["gemma3-12b", "mamba2-1.3b",
                                      "deepseek-v2-lite-16b"])
    def test_full_cache_tree(self, arch):
        cfg = get_config(arch)
        caches = lm_cache_shapes(cfg, 128, 32768)
        tree = cache_shardings(caches, MESH)
        assert jax.tree.leaves(tree)
