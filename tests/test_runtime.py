"""Fault tolerance, stragglers, data pipeline, optimizer, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import (HeartbeatMonitor, reshard_plan,
                               StragglerDetector, rebalance, plan_recovery)
from repro.launch.mesh import elastic_mesh_shape
from repro.data import DataConfig, init_state, make_batch
from repro.configs import get_config
from repro.optim import adamw, quantized_psum
from repro.checkpoint import Journal


class TestFault:
    def test_heartbeat_detects_failure(self):
        hb = HeartbeatMonitor(timeout_s=10)
        hb.beat(0, now=0.0)
        hb.beat(1, now=0.0)
        hb.beat(0, now=20.0)
        assert hb.failed(now=21.0) == [1]
        assert hb.alive(now=21.0) == [0]

    def test_reshard_plan_covers_all_shards(self):
        plan = reshard_plan([0, 1, 2, 3], [0, 2, 3], 16)
        got = sorted(s for v in plan.values() for s in v)
        assert got == list(range(16))
        sizes = [len(v) for v in plan.values()]
        assert max(sizes) - min(sizes) <= 1

    def test_elastic_mesh_preserves_model_axis(self):
        shape, axes = elastic_mesh_shape(240, model_axis=16)
        assert shape == (15, 16) and axes == ("data", "model")
        shape, _ = elastic_mesh_shape(100, model_axis=16)
        assert 100 % shape[1] == 0

    def test_plan_recovery(self, tmp_path):
        j = Journal(str(tmp_path / "j.jsonl"))
        j.commit(7, j.assign(7))
        hb = HeartbeatMonitor(timeout_s=5)
        for h in range(4):
            hb.beat(h, now=0.0)
        hb.beat(3, now=100.0)      # only 3 survives... others at t=0
        dec = plan_recovery(hb, j, devices_per_host=8, model_axis=4,
                            now=101.0)
        assert dec.restore_step == 7
        assert dec.mesh_shape[1] == 4


class TestStraggler:
    def test_detect_and_eject(self):
        det = StragglerDetector(alpha=1.0, threshold=1.4, eject_after=2)
        for _ in range(3):
            for h in range(4):
                det.observe(h, 1.0 if h else 2.0)   # host 0 slow
            s = det.stragglers()
        assert s == [0]
        assert det.ejections() == [0]

    def test_rebalance_moves_work(self):
        plan = {0: [0, 1, 2, 3], 1: [4, 5], 2: [6, 7]}
        new = rebalance(plan, straggler=0, fraction=0.5)
        assert len(new[0]) == 2
        assert sorted(s for v in new.values() for s in v) == list(range(8))


class TestData:
    def test_deterministic(self):
        cfg = get_config("qwen2-0.5b", smoke=True)
        dc = DataConfig(seed=3)
        b1, s1 = make_batch(dc, cfg, 4, 32, init_state())
        b2, _ = make_batch(dc, cfg, 4, 32, init_state())
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3, _ = make_batch(dc, cfg, 4, 32, s1)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_hosts_get_different_data(self):
        cfg = get_config("qwen2-0.5b", smoke=True)
        b1, _ = make_batch(DataConfig(host_id=0), cfg, 4, 32, init_state())
        b2, _ = make_batch(DataConfig(host_id=1), cfg, 4, 32, init_state())
        assert not np.array_equal(b1["tokens"], b2["tokens"])

    def test_zipf_skew_creates_hotspots(self):
        cfg = get_config("qwen2-0.5b", smoke=True)
        b, _ = make_batch(DataConfig(zipf_s=1.2), cfg, 8, 128, init_state())
        toks = np.asarray(b["tokens"]).reshape(-1)
        _, counts = np.unique(toks, return_counts=True)
        assert counts.max() > 32      # the paper's hot threshold is hit

    def test_labels_shift(self):
        cfg = get_config("qwen2-0.5b", smoke=True)
        b, _ = make_batch(DataConfig(), cfg, 2, 16, init_state())
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestOptim:
    def _quad_losses(self, bits, steps=60):
        cfg = adamw.AdamWConfig(peak_lr=0.1, warmup_steps=1,
                                decay_steps=1000, weight_decay=0.0,
                                state_bits=bits)
        params = {"w": jnp.ones((64,)) * 3.0}
        opt = adamw.init(params, bits)
        for _ in range(steps):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw.apply(cfg, grads, opt, params)
        return float(jnp.abs(params["w"]).max())

    @pytest.mark.parametrize("bits", [32, 16, 8])
    def test_adamw_converges_all_state_widths(self, bits):
        assert self._quad_losses(bits) < 0.5

    def test_quantized_psum_single_device(self):
        # axis size 1: quantization error only. check_rep=False because
        # the manual ring's replication cannot be statically inferred.
        mesh = jax.make_mesh((1,), ("d",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        x = jnp.linspace(-1, 1, 4096)
        f = shard_map(lambda v: quantized_psum(v, "d")[0], mesh,
                      in_specs=P(), out_specs=P(), check_rep=False)
        np.testing.assert_allclose(f(x), x, atol=2e-2)

    def test_quantized_psum_multidevice_subprocess(self):
        """8 forced host devices: quantized ring-all-reduce ~= exact psum."""
        import subprocess, sys, os
        code = (
            "import os;"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8';"
            "import jax, jax.numpy as jnp, numpy as np;"
            "from jax.experimental.shard_map import shard_map;"
            "from jax.sharding import PartitionSpec as P;"
            "from repro.optim import quantized_psum;"
            "mesh = jax.make_mesh((8,), ('d',));"
            "x = jnp.arange(8 * 512, dtype=jnp.float32)"
            ".reshape(8, 512) / 1000.0;"
            "f = shard_map(lambda v: quantized_psum(v[0], 'd')[0][None],"
            " mesh, in_specs=P('d'), out_specs=P('d'), check_rep=False);"
            "got = np.asarray(f(x));"
            "want = np.asarray(x.sum(0));"
            "err = np.abs(got - want).max() / max(np.abs(want).max(), 1);"
            "assert err < 0.05, err; print('ok', err)"
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src")
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "ok" in out.stdout


class TestServe:
    def test_group_server_serves_all_in_order(self):
        from repro.launch.serve import serve_demo
        srv = serve_demo(n_requests=9, batch_slots=4)
        assert all(r is None for r in srv.active)
        assert srv.members_served > 0
        # dynamic batch: fused steps < total tokens (grouping worked)
        assert srv.steps_fired < srv.members_served
