"""Step-profiler tests (DESIGN.md §12): every stage ablation is a
bit-exact no-op under its designated no-op config, the profiler's
fractions are a partition of the measured per-iteration cost, and the
compile accounting (one executable per ablation; telemetry counters)
holds."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.lock import (CostModel, EngineConfig, WorkloadSpec,
                             protocol_params, split_config, init_state_dyn)
from repro.core.lock import engine as E
from repro.obs import compile_log
from repro.obs.prof import (STAGE_NOOPS, profile_row, profile_step,
                            rank_table)

N_STEPS = 40


def _cfg(proto, *, txn_len=4, write_ratio=1.0, kind="hotspot_update",
         threads=8, rows=64):
    wl = WorkloadSpec(kind=kind, txn_len=txn_len, n_rows=rows,
                      write_ratio=write_ratio)
    return EngineConfig(protocol=protocol_params(proto), costs=CostModel(),
                        workload=wl, n_threads=threads, horizon=500_000)


def _run_steps(stat, dp, ablate=frozenset()):
    step = jax.jit(E._make_step(stat, dp, ablate=ablate))
    st = init_state_dyn(stat, dp)
    for _ in range(N_STEPS):
        st = step(st)
    return st


def _leaf_diffs(a, b):
    pa, _ = jax.tree_util.tree_flatten_with_path(a)
    pb, _ = jax.tree_util.tree_flatten_with_path(b)
    return [jax.tree_util.keystr(k)
            for (k, x), (_, y) in zip(pa, pb) if not jnp.array_equal(x, y)]


# (stage, config under which its ablation must be the identity)
NOOP_CASES = [
    ("dup_analysis", _cfg("mysql", txn_len=1)),
    ("deadlock_walk", _cfg("brook2pl")),
    ("ticket_grant", _cfg("mysql", kind="uniform", write_ratio=0.0)),
    ("commit_cursor", _cfg("mysql", kind="uniform", write_ratio=0.0)),
    ("group_hotspot", _cfg("mysql")),
    ("group_hotspot", _cfg("brook2pl")),
]


@pytest.mark.parametrize("stage,cfg", NOOP_CASES,
                         ids=[f"{s}-{c.protocol.name}-{c.workload.kind}"
                              f"L{c.workload.txn_len}w{c.workload.write_ratio}"
                              for s, c in NOOP_CASES])
def test_ablation_bit_exact_under_noop_config(stage, cfg):
    stat, dp = split_config(cfg)
    full = _run_steps(stat, dp)
    abl = _run_steps(stat, dp, ablate=frozenset({stage}))
    assert _leaf_diffs(full, abl) == []


def test_tick_charge_ablation_touches_only_tb():
    # under ANY config: the breakdown accumulator is write-only state
    cfg = _cfg("mysql")
    stat, dp = split_config(cfg)
    full = _run_steps(stat, dp)
    abl = _run_steps(stat, dp, ablate=frozenset({"tick_charge"}))
    diffs = _leaf_diffs(full, abl)
    assert diffs == [".g.tb"]
    # and something was actually charged — the ablation removed real work
    assert int(full.g.tb.sum()) > 0
    assert int(abl.g.tb.sum()) == 0


def test_empty_ablation_is_production_step():
    cfg = _cfg("group")
    stat, dp = split_config(cfg)
    full = _run_steps(stat, dp)
    default = _run_steps(stat, dp, ablate=frozenset())
    assert _leaf_diffs(full, default) == []


def test_unknown_stage_rejected():
    cfg = _cfg("mysql")
    stat, dp = split_config(cfg)
    with pytest.raises((AssertionError, ValueError)):
        E._make_step(stat, dp, ablate=frozenset({"nonsense"}))
    with pytest.raises(ValueError):
        profile_step(cfg, stages=("nonsense",))


def test_stage_noops_cover_prof_stages():
    assert set(STAGE_NOOPS) == set(E.PROF_STAGES)
    tested = {s for s, _ in NOOP_CASES} | {"tick_charge"}
    assert tested == set(E.PROF_STAGES)


def test_profile_step_partitions_cost():
    # two-stage profile keeps the test at 3 executables
    cfg = _cfg("mysql", threads=16)
    prof = profile_step(cfg, n_iters=16, repeats=1,
                        stages=("commit_cursor", "tick_charge"))
    assert prof.compiles == 3
    names = [s.stage for s in prof.stages]
    assert names[-1] == "other"
    assert set(names) == {"commit_cursor", "tick_charge", "other"}
    assert abs(sum(s.fraction for s in prof.stages) - 1.0) < 1e-9
    assert all(s.us_per_iter >= 0.0 for s in prof.stages)
    assert prof.us_per_iter > 0.0
    assert prof.dominant.stage != "other"
    # report renderers accept the profile
    assert "dominant:" in rank_table(prof)
    row = profile_row("profile_test", prof)
    assert row.startswith("profile_test,") and "dominant=" in row


def test_compile_telemetry_counts_fresh_compiles():
    t0 = compile_log.snapshot()

    @jax.jit
    def probe(x):
        return jnp.cumsum(x * 3.0)

    probe(jnp.arange(101.0)).block_until_ready()
    d = compile_log.delta(t0)
    assert d["backend_compiles"] >= 1
    assert d["compile_time_s"] > 0.0
    assert any("probe" in name for name in d["fns"])
    # hlo size of an AOT executable is non-trivial
    compiled = jax.jit(lambda x: x @ x).lower(jnp.ones((8, 8))).compile()
    assert compile_log.hlo_module_bytes(compiled) > 100


def test_strict_mode_names_unregistered_entry_points():
    @jax.jit
    def sneaky(x):
        return x * 2 + 1

    sneaky(jnp.arange(7)).block_until_ready()
    mod = sneaky.__wrapped__.__module__
    found = compile_log.unregistered_compiles(prefixes=(mod,))
    assert any("sneaky" in name for name in found)
    # registered entry points are never reported
    compile_log.register(sneaky)
    try:
        assert not any("sneaky" in n
                       for n in compile_log.unregistered_compiles(
                           prefixes=(mod,)))
    finally:
        compile_log._EXTRA.remove(sneaky)
