import os
import sys

# tests must see exactly ONE device (the dry-run's 512-device trick is
# confined to launch/dryrun.py and subprocess tests)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
