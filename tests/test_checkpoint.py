"""Checkpoint/journal: 2PC commit, crash idempotence, ordered recovery."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, Journal


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.asarray(3.5)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree()
    ck.save(10, t)
    got = ck.restore(None, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    for s in (5, 10, 15):
        ck.save(s, tree(s))
    ck.wait()
    assert ck.latest_step() == 15


def test_crash_between_prepare_and_commit_is_ignored(tmp_path):
    """A tmp dir without the committing rename must not be restored —
    the 2PC argument of §4.3 applied to checkpoints."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, tree(1))
    # simulate a crashed Prepare: stray tmp dir + journal assign w/o commit
    os.makedirs(os.path.join(str(tmp_path), "step_00000099.tmp-dead"))
    with open(os.path.join(str(tmp_path), "journal.jsonl"), "a") as f:
        f.write(json.dumps({"event": "assign", "step": 99, "order": 77})
                + "\n")
    ck2 = Checkpointer(str(tmp_path), async_save=False)
    assert ck2.latest_step() == 1            # 99 never committed
    restored = ck2.restore(None, jax.tree.map(jnp.zeros_like, tree(1)))
    assert restored is not None


def test_journal_recovery_is_idempotent(tmp_path):
    p = os.path.join(str(tmp_path), "j.jsonl")
    j = Journal(p)
    o1 = j.assign(1)
    j.commit(1, o1)
    o2 = j.assign(2)                          # crash before commit
    del j
    j2 = Journal(p)                           # recovery #1
    assert j2.latest_committed() == 1
    del j2
    j3 = Journal(p)                           # recovery #2 (idempotent)
    assert j3.latest_committed() == 1
    o3 = j3.assign(3)
    assert o3 > o2                            # monotone hot_update_order


def test_gc_keeps_recent(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    for s in range(1, 7):
        ck.save(s, tree(s))
    ck.gc(keep=2)
    kept = sorted(glob.glob(os.path.join(str(tmp_path), "step_*")))
    assert len(kept) == 2


def test_restore_into_new_sharding_structure(tmp_path):
    """Restore is sharding-agnostic: elastic re-mesh restores fine."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree(3)
    ck.save(4, t)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got = ck.restore(4, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
