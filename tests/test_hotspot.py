"""Hotspot-attribution tests (DESIGN.md §14): contention-accumulator
conservation (per run and per governed segment), attribution-off
bit-exactness and zero-recompile, the blame matrix, the unified
queue-threshold detector, export schema validity with hotspot lanes,
and the Prometheus serving-metrics registry."""
import json
import re

import jax
import numpy as np
import pytest

from repro.core import (DEFAULT_THRESHOLD, detect_hot, detect_hot_queue,
                        init_hotspot, update_hotspot_queue)
from repro.core.lock import WorkloadSpec, simulate
from repro.core.lock import engine as E
from repro.core.lock.engine import (CA_GRANTS, CA_QMAX, CA_WAIT, N_CA,
                                    EngineConfig, TB_LOCKWAIT)
from repro.core.lock.costs import CostModel, protocol_params
from repro.core.lock.metrics import delta_globals, extract, hotspot_rows
from repro.obs import (check_ca_conservation, events_host, gini,
                       hotspot_lane_events, hotspot_summary,
                       simulate_traced, to_chrome_trace, wait_share)
from repro.obs.blame import blame_matrix, blame_table, critical_path
from repro.obs.export import _wait_spans, wait_profile
from repro.serving import (MetricFamily, ServeCell, ServingMetrics,
                           poisson, serve)

ZIPF = WorkloadSpec(kind="zipf", txn_len=4, n_rows=512, zipf_s=0.9)
HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
PROTOCOLS = ["mysql", "o1", "o2", "group", "bamboo", "brook2pl"]
HORIZON = 60_000


def leaves(tree):
    return jax.tree_util.tree_leaves(tree)


class TestConservation:
    """sum(ca[wait]) == tb[lock_wait] exactly — the accumulator is a
    lossless per-record decomposition of a number the engine already
    reports (ISSUE acceptance gate: 6 protocols x 3 seeds)."""

    @pytest.mark.parametrize("proto", PROTOCOLS)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_whole_run(self, proto, seed):
        s = simulate(proto, ZIPF, n_threads=24, horizon=HORIZON,
                     seed=seed, attrib=True)
        total = check_ca_conservation(s)
        assert total > 0, "zipf under contention must produce lock wait"

    def test_per_segment_windows(self):
        """Conservation holds on every delta_globals window of a
        segmented run, not just end-to-end — both sides charge the same
        per-iteration mask, so every prefix (hence every window) agrees."""
        cfg = EngineConfig(
            protocol=protocol_params("mysql"), costs=CostModel(),
            workload=ZIPF, n_threads=24, horizon=HORIZON, attrib=True)
        stat, dp = E.split_config(cfg)
        s = E.init_state_dyn(stat, dp)
        g_prev = jax.device_get(s.g)
        seen = 0
        for k in range(4):
            until = HORIZON * (k + 1) // 4
            s, _snap = E.run_segment(stat, dp, s, until)
            g_now = jax.device_get(s.g)
            w = delta_globals(g_prev, g_now)
            seen += check_ca_conservation(w)
            g_prev = g_now
        # windows partition the run: their wait totals sum to the run's
        assert seen == check_ca_conservation(s)

    def test_hotspot_rows_match_accumulator(self):
        s = simulate("mysql", ZIPF, n_threads=24, horizon=HORIZON,
                     attrib=True)
        ca = np.asarray(s.g.ca, dtype=np.int64)
        rows = hotspot_rows(ca, top_k=4)
        assert rows and rows == sorted(
            rows, key=lambda r: (-r["wait_ticks"], -r["grants"]))
        for r in rows:
            assert r["wait_ticks"] == int(ca[CA_WAIT, r["row"]])
            assert r["grants"] == int(ca[CA_GRANTS, r["row"]])

    def test_extract_populates_hotspots(self):
        s = simulate("mysql", ZIPF, n_threads=24, horizon=HORIZON,
                     attrib=True)
        r = extract("mysql", 24, s)
        assert r.hotspots and r.hotspots[0]["wait_ticks"] > 0
        s_off = simulate("mysql", ZIPF, n_threads=24, horizon=HORIZON)
        assert extract("mysql", 24, s_off).hotspots == []


class TestAttribOff:
    """attrib=False must be the stock engine — the accumulator is
    write-only, so disabling it changes exactly nothing else."""

    @pytest.mark.parametrize("proto", ["mysql", "brook2pl"])
    def test_bit_exact_off_vs_absent(self, proto):
        s_on = simulate(proto, ZIPF, n_threads=24, horizon=HORIZON,
                        attrib=True)
        s_off = simulate(proto, ZIPF, n_threads=24, horizon=HORIZON)
        diff = [i for i, (a, b) in enumerate(zip(leaves(s_on),
                                                 leaves(s_off)))
                if not np.array_equal(np.asarray(a), np.asarray(b))]
        # exactly one leaf differs: the ca accumulator itself
        assert len(diff) == 1
        assert np.all(np.asarray(s_off.g.ca) == 0)
        assert np.asarray(s_on.g.ca).sum() > 0

    def test_flag_is_traced_no_recompile(self):
        n0 = E._run_dyn._cache_size()
        simulate("mysql", ZIPF, n_threads=24, horizon=5_000)
        n1 = E._run_dyn._cache_size()
        simulate("mysql", ZIPF, n_threads=24, horizon=5_000, attrib=True)
        assert E._run_dyn._cache_size() == n1, \
            "attrib flip must not add a compile-cache entry"
        assert n1 <= n0 + 1


def _ev(rows):
    """Synthetic event table from (ts, tid, row, ev) tuples."""
    ts, tid, row, ev = (np.asarray(c, dtype=np.int32)
                        for c in zip(*rows))
    return {"ts": ts, "tid": tid, "row": row, "ev": ev,
            "n": len(rows), "dropped": 0, "cap": 4096}


# event ids (match obs.trace.EVENTS)
GRANT, WAIT, TIMEOUT, VICTIM, RELEASE, GJOIN, COMMIT, ABORT = range(8)


class TestBlame:
    def test_single_blocker_full_attribution(self):
        # t0 holds row 5 over [0, 10); t1 waits [2, 10) then is granted
        ev = _ev([(0, 0, 5, GRANT), (2, 1, 5, WAIT),
                  (10, 0, 5, RELEASE), (10, 1, 5, GRANT),
                  (12, 1, -1, COMMIT), (15, 0, -1, COMMIT)])
        b = blame_matrix(ev, end=20)
        assert b.total_wait == 8 and b.n_spans == 1
        assert b.matrix == {(0, 0): {5: 8}}
        assert b.per_txn == {(0, 0): 8}
        assert b.per_record == {5: 8}
        assert b.unattributed == {}

    def test_attempt_numbering_after_abort(self):
        # t0's first attempt aborts; its SECOND attempt holds the row
        # while t1 waits — blame lands on attempt #1, not #0
        ev = _ev([(0, 0, 5, GRANT), (3, 0, -1, ABORT),
                  (4, 0, 5, GRANT), (5, 1, 5, WAIT),
                  (9, 0, -1, COMMIT), (9, 1, 5, GRANT),
                  (11, 1, -1, COMMIT)])
        b = blame_matrix(ev, end=20)
        assert b.per_txn == {(0, 1): 4}
        assert b.matrix == {(0, 1): {5: 4}}

    def test_unattributed_without_holder(self):
        # nobody recorded holding row 7: the span stays unattributed
        ev = _ev([(2, 1, 7, WAIT), (10, 1, 7, GRANT),
                  (12, 1, -1, COMMIT)])
        b = blame_matrix(ev, end=20)
        assert b.total_wait == 8 and b.per_txn == {}
        assert b.unattributed == {7: 8}

    def test_critical_path_chain(self):
        # t2 waits on t1 (row 3), t1 waits on t0 (row 5): 2 hops
        ev = _ev([(0, 0, 5, GRANT), (0, 1, 3, GRANT),
                  (1, 2, 3, WAIT), (2, 1, 5, WAIT),
                  (10, 0, -1, COMMIT), (10, 1, 5, GRANT),
                  (12, 1, -1, COMMIT), (12, 2, 3, GRANT),
                  (14, 2, -1, COMMIT)])
        path = critical_path(ev, end=20)
        assert [h["tid"] for h in path] == [2, 1]
        assert [h["row"] for h in path] == [3, 5]
        # blocker is the holding (tid, attempt) pair
        assert path[0]["blocker"] == (1, 0)
        assert path[1]["blocker"] == (0, 0)

    def test_per_record_matches_wait_profile_on_real_trace(self):
        s, tb = simulate_traced("mysql", ZIPF, n_threads=24,
                                horizon=HORIZON, cap=65_536)
        ev = events_host(tb)
        end = int(s.g.now)
        b = blame_matrix(ev, end=end)
        spans = list(_wait_spans(ev, end=end))
        per_row = {}
        for _tid, row, t0, t1, _e in spans:
            per_row[row] = per_row.get(row, 0) + (t1 - t0)
        assert b.per_record == per_row
        assert b.n_spans == len(spans)
        assert "blame table" in blame_table(ev, end=end)


class TestDetectorUnification:
    """One threshold rule (queue depth > 32) across the batch detector,
    the engine, and the accumulator's CA_QMAX lane."""

    def test_queue_32_promote_rule(self):
        q = np.zeros(16, dtype=np.int32)
        q[3] = DEFAULT_THRESHOLD          # boundary: NOT hot (strict >)
        q[7] = DEFAULT_THRESHOLD + 1      # hot
        hot = np.asarray(detect_hot_queue(q))
        assert not hot[3] and hot[7] and hot.sum() == 1

    def test_batch_detector_agrees_with_queue_detector(self):
        ids = np.repeat(np.arange(4), [40, 33, 32, 1])
        from repro.core import batch_counts
        counts = batch_counts(ids, 8)
        assert np.array_equal(np.asarray(detect_hot(ids, 8)),
                              np.asarray(detect_hot_queue(counts)))

    def test_promote_demote_cycle(self):
        st = init_hotspot(8)
        deep = np.zeros(8, dtype=np.int32)
        deep[2] = 40
        st = update_hotspot_queue(st, deep)
        assert bool(st.hot[2]) and st.hot.sum() == 1
        # drained queues: EMA decays, row demotes once below the floor
        calm = np.zeros(8, dtype=np.int32)
        for _ in range(40):
            st = update_hotspot_queue(st, calm)
        assert not bool(st.hot[2])

    def test_engine_qmax_feeds_the_same_rule(self):
        s = simulate("mysql", ZIPF, n_threads=64, horizon=HORIZON,
                     attrib=True)
        ca = np.asarray(s.g.ca)
        summ = hotspot_summary(s, ZIPF)
        assert summ["n_hot_rule"] == int(
            np.asarray(detect_hot_queue(ca[CA_QMAX])).sum())


class TestExportSchema:
    """Chrome-trace export validity: json round-trip, required fields,
    monotonic per-track timestamps, counter lanes (satellite)."""

    def _trace(self, lanes=0):
        s, tb = simulate_traced("mysql", ZIPF, n_threads=24,
                                horizon=HORIZON, cap=65_536)
        ev = events_host(tb)
        return to_chrome_trace(ev, label="t", end=int(s.g.now),
                               hotspot_lanes=lanes), ev

    def test_roundtrip_and_required_fields(self):
        doc, _ = self._trace()
        doc2 = json.loads(json.dumps(doc))
        assert doc2["traceEvents"]
        for e in doc2["traceEvents"]:
            assert e["ph"] in ("X", "i", "M", "C")
            assert "pid" in e and "tid" in e and "name" in e
            if e["ph"] != "M":
                assert isinstance(e["ts"], (int, float))
                assert e["ts"] >= 0
            if e["ph"] == "X":
                assert e["dur"] >= 0

    def test_monotonic_per_track(self):
        doc, _ = self._trace(lanes=4)
        tracks = {}
        for e in doc["traceEvents"]:
            if e["ph"] == "M":
                continue
            tracks.setdefault((e["pid"], e["tid"], e["ph"]),
                              []).append(e["ts"])
        assert tracks
        for key, ts in tracks.items():
            assert all(a <= b for a, b in zip(ts, ts[1:])), key

    def test_hotspot_lanes(self):
        doc, ev = self._trace(lanes=3)
        counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
        assert counters
        lanes = {e["name"] for e in counters}
        assert len(lanes) <= 3
        for name in lanes:
            series = [e for e in counters if e["name"] == name]
            vals = [list(e["args"].values())[0] for e in series]
            assert all(v >= 0 for v in vals), name
            # depth timeline from +-1 span deltas must return to its
            # floor by the end of the capture window
            assert vals[-1] == 0, name
        # lanes are additive: base export unchanged (lanes bring their
        # counter events plus their track-name "M" metadata, nothing else)
        base, _ = self._trace()
        extra = [e for e in doc["traceEvents"]
                 if e["ph"] != "C" and not (
                     e["ph"] == "M" and "hotspot" in str(
                         e.get("args", {}).get("name", "")))]
        assert extra == base["traceEvents"]

    def test_lane_events_standalone(self):
        _, ev = self._trace()
        evs = hotspot_lane_events(ev, top_k=2, end=200_000)
        assert evs and all(e["ph"] in ("C", "M") for e in evs)


class TestServingMetrics:
    def _record_like(self):
        res_reg = ServingMetrics(sla_budget=0.01, top_k=3)
        w = WorkloadSpec(kind="zipf", n_rows=256, txn_len=8, zipf_s=1.2)
        cells = [
            ServeCell(name="on", schedule=poisson(0.004, 40_000, seed=1),
                      workload=w, n_threads=8, preset="mysql",
                      sla_us=500.0, attrib=True),
            ServeCell(name="off", schedule=poisson(0.004, 40_000, seed=2),
                      workload=w, n_threads=8, preset="mysql",
                      sla_us=500.0),
        ]
        res = serve(cells, seg_ticks=10_000, metrics_registry=res_reg)
        return res_reg, res

    def test_counters_match_serving_totals(self):
        reg, res = self._record_like()
        for name in ("on", "off"):
            sv = res.serving[name]
            assert reg.get("repro_serving_arrivals_total",
                           cell=name) == sv.arrived
            assert reg.get("repro_serving_completed_total",
                           cell=name) == sv.completed
            assert reg.get("repro_serving_sla_miss_total",
                           cell=name) == sv.sla_miss
            assert reg.get("repro_serving_commits_total",
                           cell=name) == sv.engine.commits

    def test_hotspot_gauges_gated_by_attrib(self):
        reg, res = self._record_like()
        fam = reg.families["repro_hotspot_wait_ticks"].samples
        assert any(("cell", "on") in k for k in fam)
        assert not any(("cell", "off") in k for k in fam)
        # record JSON mirrors the gating
        assert any(rec["hotspots"] for rec in res.segments["on"])
        assert all(rec["hotspots"] == [] for rec in res.segments["off"])

    def test_exposition_format(self):
        reg, _ = self._record_like()
        text = reg.render()
        assert text.endswith("\n")
        sample = re.compile(
            r'^[a-z_:][a-z0-9_:]*(\{[a-z_]+="[^"]*"'
            r'(,[a-z_]+="[^"]*")*\})? -?\d+(\.\d+)?(e[+-]?\d+)?$',
            re.IGNORECASE)
        seen_types = {}
        for line in text.strip().splitlines():
            if line.startswith("# TYPE"):
                _, _, name, kind = line.split(None, 3)
                seen_types[name] = kind
            elif not line.startswith("#"):
                assert sample.match(line), line
        assert seen_types["repro_serving_arrivals_total"] == "counter"
        assert seen_types["repro_serving_queue_depth"] == "gauge"

    def test_counters_monotonic_and_guarded(self):
        f = MetricFamily("x_total", "counter", "h")
        f.inc(3, cell="a")
        f.inc(2, cell="a")
        assert f.get(cell="a") == 5
        with pytest.raises(ValueError):
            f.inc(-1, cell="a")

    def test_dump_and_http(self, tmp_path):
        import urllib.request
        reg = ServingMetrics()
        f = reg.families["repro_serving_queue_depth"]
        f.set(7, cell="c")
        p = tmp_path / "m.prom"
        reg.dump(p)
        assert p.read_text() == reg.render()
        srv = reg.serve_http()
        try:
            port = srv.server_address[1]
            body = urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics").read().decode()
            assert body == reg.render()
            assert urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics").status == 200
        finally:
            srv.shutdown()


class TestStoreSchema:
    def test_v4_readable_and_current(self):
        from repro.sweep import store
        assert store.SCHEMA == "repro.sweep/v4"
        for v in ("v1", "v2", "v3", "v4"):
            assert f"repro.sweep/{v}" in store.SCHEMAS_READABLE


class TestReports:
    def test_gini_bounds(self):
        assert gini(np.ones(10)) == pytest.approx(0.0, abs=1e-9)
        one_hot = np.zeros(100)
        one_hot[0] = 5.0
        assert gini(one_hot) > 0.95
        assert gini(np.zeros(4)) == 0.0

    def test_wait_share_sums_to_one(self):
        s = simulate("mysql", ZIPF, n_threads=24, horizon=HORIZON,
                     attrib=True)
        ws = wait_share(s)
        assert ws.shape == (ZIPF.n_rows,)
        assert ws.sum() == pytest.approx(1.0)

    def test_summary_zipf_ground_truth(self):
        s = simulate("mysql", ZIPF, n_threads=24, horizon=HORIZON,
                     attrib=True)
        h = hotspot_summary(s, ZIPF)
        assert 0 < h["gini_zipf"] < 1
        assert h["skew_amplification"] == pytest.approx(
            h["gini_wait"] / h["gini_zipf"])
        assert 0 <= h["top1_share"] <= h["top10_share"] <= 1
