"""Property tests for the adapted technique: conflict-group apply and
dependency-list semantics."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (group_apply, hotspot_apply, scatter_serial,
                        form_groups, detect_hot, init_hotspot,
                        update_hotspot, DependencyList, DependencyError)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 300),
    v=st.integers(1, 64),
    d=st.sampled_from([1, 4, 9]),
    hot_frac=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_group_apply_equals_serial(n, v, d, hot_frac, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, v, n).astype(np.int32)
    n_hot = int(n * hot_frac)
    if n_hot:
        ids[:n_hot] = rng.integers(0, v)      # force a heavy hotspot
    ids = jnp.asarray(ids)
    upd = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    want = scatter_serial(table, ids, upd)
    got_g = group_apply(table, ids, upd)
    got_h = hotspot_apply(table, ids, upd, threshold=8)
    np.testing.assert_allclose(got_g, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_h, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 200), v=st.integers(1, 32),
       seed=st.integers(0, 2**31 - 1))
def test_form_groups_structure(n, v, seed):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    g = form_groups(ids)
    # group sizes at leaders sum to n; leader count = distinct ids
    assert int(g.group_size.sum()) == n
    assert int(g.is_leader.sum()) == len(np.unique(np.asarray(ids)))
    # sorted ids non-decreasing (dependency order is total per group)
    s = np.asarray(g.sorted_ids)
    assert (np.diff(s) >= 0).all()


def test_hotspot_detector_promote_demote():
    ids = jnp.concatenate([jnp.zeros(40, jnp.int32),
                           jnp.arange(1, 11, dtype=jnp.int32)])
    hot = detect_hot(ids, 16, threshold=32)
    assert bool(hot[0]) and not bool(hot[1:].any())
    st_ = init_hotspot(16)
    st_ = update_hotspot(st_, ids, threshold=32)
    assert bool(st_.hot[0])
    cold = jnp.arange(1, 11, dtype=jnp.int32)
    for _ in range(40):                       # sweeper demotes as EMA decays
        st_ = update_hotspot(st_, cold, threshold=32)
    assert not bool(st_.hot[0])


class TestDependencyList:
    def test_commit_order_enforced(self):
        dl = DependencyList()
        a, b, c = dl.assign(), dl.assign(), dl.assign()
        assert dl.can_commit(a) and not dl.can_commit(b)
        with pytest.raises(DependencyError):
            dl.commit(b)
        dl.commit(a)
        dl.commit(b)
        dl.commit(c)

    def test_rollback_reverse_order(self):
        dl = DependencyList()
        a, b, c = dl.assign(), dl.assign(), dl.assign()
        with pytest.raises(DependencyError):
            dl.rollback(a)                    # not the tail
        dl.rollback(c)
        dl.rollback(b)
        dl.rollback(a)

    def test_cascade_from(self):
        dl = DependencyList()
        orders = [dl.assign() for _ in range(5)]
        rolled = dl.rollback_all_from(orders[2])
        assert rolled == [orders[4], orders[3], orders[2]]
        assert dl.open_orders == tuple(orders[:2])

    def test_recover_reverse_sequence(self):
        dl = DependencyList()
        seq = dl.recover([3, 7, 5])
        assert seq == [7, 5, 3]
        assert dl.assign() == 8               # monotone after recovery
