"""Fig 19: hotspot attribution heatmap — who owns the wait, per record.

Runs the skew x protocol grid with the engine's per-record contention
accumulator on (``attrib=True``) and reports where lock-wait concentrates:
top-1 / top-10 record wait share, the Gini coefficient of the wait
distribution, and the amplification of that Gini over the zipf access
distribution's own (``skew_amp`` — how much the protocol *concentrates*
contention beyond what the access pattern dictates). Every point asserts
exact conservation: the accumulator's wait ticks sum to the
TickBreakdown's lock_wait bin, cold+hot.

Measured shape (T=128, zipf s=1.2): top-1 share o2 >= mysql ~ o1 > group
— early release slightly *sharpens* concentration (shorter holds, faster
requeue on the same hot row), group's shared grants spread it, and
brook2pl is degenerate-concentrated (~0.98: ordered acquire funnels every
conflicting txn into the queue of its lowest-ranked hot row). The
benchmark records the shares so the trajectory catches regressions in
either direction.

A final traced row pairs the same mysql/zipf cell through the event
buffer into the blame matrix (``obs.blame``): top-blocker share of
attributed wait and the longest blocking chain's length/duration — the
"which transaction do I kill" view the accumulator alone cannot give.
"""
import time

from .common import emit
from repro.core.lock import WorkloadSpec, simulate
from repro.obs import simulate_traced, events_host
from repro.obs.blame import blame_matrix, critical_path
from repro.obs.hotspot import check_ca_conservation, hotspot_summary

PROTOCOLS = ("mysql", "o1", "o2", "group", "brook2pl")
SKEWS = (0.6, 0.9, 1.2)


def _point(proto: str, skew: float, threads: int, horizon: int) -> str:
    w = WorkloadSpec(kind="zipf", txn_len=8, n_rows=2048, zipf_s=skew)
    t0 = time.perf_counter()
    s = simulate(proto, w, n_threads=threads, horizon=horizon,
                 attrib=True)
    wall_us = (time.perf_counter() - t0) * 1e6
    check_ca_conservation(s)        # exact, or this point dies loudly
    h = hotspot_summary(s, w)
    return (f"fig19_{proto}_s{skew:g},{wall_us:.1f},"
            f"top1_share={h['top1_share']:.4f};"
            f"top10_share={h['top10_share']:.4f};"
            f"gini={h['gini_wait']:.4f};"
            f"skew_amp={h.get('skew_amplification', 0.0):.4f};"
            f"wait_ticks={h['wait_ticks']};"
            f"rows_waited={h['rows_waited']};"
            f"n_hot={h['n_hot_rule']};conserved=1")


def _blame_row(threads: int, horizon: int) -> str:
    w = WorkloadSpec(kind="zipf", txn_len=8, n_rows=2048, zipf_s=1.2)
    t0 = time.perf_counter()
    s, tb = simulate_traced("mysql", w, n_threads=threads,
                            horizon=horizon, cap=131_072, attrib=True)
    wall_us = (time.perf_counter() - t0) * 1e6
    ev = events_host(tb)
    end = int(s.g.now)
    b = blame_matrix(ev, end=end)
    top = b.top_blockers(1)
    top_share = (top[0][1] / b.total_wait) if (top and b.total_wait) else 0.0
    unattr = (sum(b.unattributed.values()) / b.total_wait
              if b.total_wait else 0.0)
    path = critical_path(ev, end=end)
    return (f"fig19_blame_mysql,{wall_us:.1f},"
            f"spans={b.n_spans};blocked_rows={len(b.per_record)};"
            f"top_blocker_share={top_share:.4f};"
            f"unattributed_frac={unattr:.4f};"
            f"path_hops={len(path)};"
            f"path_ticks={sum(h['dur'] for h in path)};"
            f"dropped={int(ev['dropped'])}")


def run(quick=True, smoke=False):
    if smoke:
        protocols, skews, threads, horizon = \
            ("mysql", "group"), (1.2,), 32, 60_000
    elif quick:
        protocols, skews, threads, horizon = PROTOCOLS, SKEWS, 64, 120_000
    else:
        protocols, skews, threads, horizon = PROTOCOLS, SKEWS, 256, 400_000
    rows = [_point(p, s, threads, horizon)
            for s in skews for p in protocols]
    rows.append(_blame_row(min(threads, 64), min(horizon, 150_000)))
    return emit(rows)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (2 protocols, 1 skew)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke)
