"""Fig 7: hotspot workloads varying write ratio and transaction length."""
import dataclasses
from .common import cc_point, emit
from repro.core.lock import WorkloadSpec

PROTOS = ["mysql", "o2", "group"]


def run(quick=True):
    horizon = 150_000 if quick else 600_000
    rows = []
    base = WorkloadSpec(kind="hotspot_update", txn_len=8, n_rows=4096)
    for wr in ([0.25, 0.75] if quick else [0.1, 0.25, 0.5, 0.75, 0.9]):
        w = dataclasses.replace(base, write_ratio=wr)
        for p in PROTOS:
            row, _ = cc_point(p, w, 256, horizon,
                              name=f"fig7a_{p}_wr{wr}")
            rows.append(row)
    for tl in ([2, 12] if quick else [2, 6, 12, 20]):
        w = dataclasses.replace(base, txn_len=tl, write_ratio=0.5)
        for p in PROTOS:
            row, _ = cc_point(p, w, 256, horizon,
                              name=f"fig7b_{p}_tl{tl}")
            rows.append(row)
    return emit(rows)


if __name__ == "__main__":
    run()
