"""§6.4.6 failure recovery: crash the engine mid-run, measure recovery
work (redo volume + ordered rollback of in-flight hotspot transactions in
reverse hot_update_order)."""
import time

import jax.numpy as jnp

from .common import emit
from repro.core.lock import (simulate, extract, WorkloadSpec, CostModel,
                             TICKS_PER_SEC)

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)


def run(quick=True):
    horizon = 150_000 if quick else 600_000
    rows = []
    cm = CostModel()
    for proto in ["mysql", "group"]:
        t0 = time.perf_counter()
        s = simulate(proto, HOT, n_threads=256, horizon=horizon, costs=cm)
        wall = (time.perf_counter() - t0) * 1e6
        r = extract(proto, 256, s)
        # crash now: in-flight (applied, uncommitted) updates need ordered
        # rollback; committed redo volume needs replay
        inflight = int((s.th.applied & (s.th.ticket >= 0)).sum())
        redo = int(s.g.commits)
        # recovery model: redo at 1us/record + serial rollbacks (§5.3 is
        # single-threaded, reverse hot_update_order)
        rec_ticks = redo * 10 + inflight * (cm.rb_base + cm.rb_per_op)
        rows.append(
            f"fig14_{proto},{wall:.0f},tps={r.tps:.0f};inflight={inflight}"
            f";redo={redo};recovery_ms={rec_ticks / 10_000:.2f}")
    return emit(rows)


if __name__ == "__main__":
    run()
