"""Fig 15 (extension): adaptive governor vs fixed protocols under drift.

Three non-stationary scenarios (DESIGN.md §7.4), each run as governed
segmented cells on one shape bucket:

* ``hot_migration`` — the FiT hot account set jumps between key-space
  sites (shifting hotspot): group locking dominates every phase; the
  governor's job is to find and hold it (convergence, not switching).
* ``skew_ramp``    — Zipf skew ramps 0.3 -> 0.7 over multi-row write
  transactions: the cheap queue path wins the low-skew phase (+~30%),
  then detection-free protocols hit the deadlock valley and strict 2PL
  wins by 4-10x — a fixed choice loses one phase or the other.
* ``flash_crowd``  — a write flash crowd (write-ratio step 0.25 -> 1.0)
  concentrating onto hot keys (skew 0.4 -> 0.8) mid-run.

Costs use the lock-manager-bound calibration (cheap row ops and commit
bookkeeping, unchanged lock-path costs) so protocol overheads — the
paper's subject — dominate txn time. Emits one row per (scenario, cell)
plus a ``*_adv`` row with adaptive-vs-best-fixed commit ratios; the
acceptance bar is ratio > 1 on at least two scenarios for the rule
governor.
"""
from .common import emit
from repro.adaptive import (EpsilonGreedyPolicy, FixedPolicy, GovernorCell,
                            QueueRulePolicy, preset_timeline, run_governed)
from repro.core.lock import (CostModel, WorkloadSpec, flash_crowd,
                             hot_migration, skew_ramp)
from repro.sweep import summarize

CM = CostModel(op_exec=20, commit_base=30)   # lock-manager-bound OLTP
FIXED = ("mysql", "o2", "group")


def scenarios(quick: bool):
    n_seg = 12 if quick else 24
    m = 1 if quick else 3
    mig = WorkloadSpec(kind="fit", txn_len=2, n_rows=4096, n_hot=1)
    ramp = WorkloadSpec(kind="zipf", txn_len=4, n_rows=8192)
    crowd = WorkloadSpec(kind="hotspot_mix", txn_len=2, n_rows=4096,
                         zipf_s=0.4, write_ratio=0.25)
    return [
        ("hot_migration", 128, 180_000 * m, n_seg,
         hot_migration(mig, n_seg, n_sites=4, period=max(n_seg // 4, 1))),
        ("skew_ramp", 64, 240_000 * m, n_seg,
         skew_ramp(ramp, n_seg, lo=0.3, hi=0.7)),
        ("flash_crowd", 64, 180_000 * m, n_seg,
         flash_crowd(crowd, n_seg, at=0.5, write_lo=0.25, write_hi=1.0,
                     skew_hi=0.8)),
    ]


def run(quick=True):
    out = []
    for scen, T, horizon, n_seg, drift in scenarios(quick):
        cells = [GovernorCell(f"fig15_{scen}_{p}", FixedPolicy(p), drift,
                              T, costs=CM) for p in FIXED]
        cells += [
            GovernorCell(f"fig15_{scen}_rule", QueueRulePolicy(), drift,
                         T, costs=CM),
            GovernorCell(f"fig15_{scen}_greedy", EpsilonGreedyPolicy(),
                         drift, T, costs=CM),
        ]
        res = run_governed(cells, horizon=horizon, n_segments=n_seg)
        out += summarize(res)
        best_name, best = max(
            ((p, res[f"fig15_{scen}_{p}"].commits) for p in FIXED),
            key=lambda kv: kv[1])
        rule_c = res[f"fig15_{scen}_rule"].commits
        greedy_c = res[f"fig15_{scen}_greedy"].commits
        tl = preset_timeline(res, f"fig15_{scen}_rule")
        switches = sum(1 for a, b in zip(tl, tl[1:]) if a != b)
        out.append(
            f"fig15_{scen}_adv,0,"
            f"rule_vs_best={rule_c / max(best, 1):.3f}"
            f";greedy_vs_best={greedy_c / max(best, 1):.3f}"
            f";best_fixed={best_name};rule_switches={switches}"
            f";compiles={res.n_compiles}")
    return emit(out)


if __name__ == "__main__":
    run()
