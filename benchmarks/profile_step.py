"""Per-stage step profiler: what the future Pallas kernel must fuse.

One row per (protocol, T, L): ranked stage fractions of the engine
step's per-iteration wall cost, measured by stage ablation
(``repro.obs.prof``, DESIGN.md §12). The point of the table is the
``dominant=`` column — on the paper's hotspot shape the T×L scan work
(commit-cursor segment reductions + dup analysis) is where the iteration
goes, which is exactly the fusion target the ROADMAP's "Pallas-kernel
the engine hot path" item needs named before any kernel is written.

Rows also carry ``compile_s``/``hlo_bytes`` for the full-step executable
(via ``obs.compile_log`` telemetry) so BENCH_run.json tracks compile
cost next to runtime cost per profiled shape.
"""
import time

from .common import emit
from repro.core.lock import CostModel, EngineConfig, WorkloadSpec, \
    protocol_params
from repro.obs import compile_log
from repro.obs.prof import profile_row, profile_step, rank_table

HOT = WorkloadSpec(kind="hotspot_update", txn_len=4, n_rows=512)

# (protocol, n_threads) grid; quick mode keeps it to the acceptance pair
GRID_QUICK = (("mysql", 64), ("brook2pl", 64))
GRID_FULL = (("mysql", 64), ("mysql", 256),
             ("brook2pl", 64), ("brook2pl", 256),
             ("o2", 256))


def _cfg(proto: str, threads: int) -> EngineConfig:
    return EngineConfig(protocol=protocol_params(proto), costs=CostModel(),
                        workload=HOT, n_threads=threads, horizon=2_000_000)


def run(quick=True):
    grid = GRID_QUICK if quick else GRID_FULL
    n_iters = 128 if quick else 512
    repeats = 3 if quick else 5
    rows = []
    for proto, threads in grid:
        tele0 = compile_log.snapshot()
        t0 = time.perf_counter()
        prof = profile_step(_cfg(proto, threads), n_iters=n_iters,
                            repeats=repeats)
        wall_s = time.perf_counter() - t0
        tele = compile_log.delta(tele0)
        print(f"# {rank_table(prof).replace(chr(10), chr(10) + '# ')}")
        row = profile_row(f"profile_{proto}_T{threads}", prof)
        rows.append(f"{row};compile_s={tele['compile_time_s']:.2f};"
                    f"profile_wall_s={wall_s:.2f}")
    return emit(rows)


if __name__ == "__main__":
    run(quick=True)
