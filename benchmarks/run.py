"""Benchmark aggregator: one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV. ``--full`` widens sweeps;
``--only fig08`` runs one module; ``--json PATH`` additionally writes the
parsed rows, per-module wall times, compile telemetry (jit-cache deltas,
XLA compile seconds, the slowest compiled functions), and per-module
sweep accounting (vmapped lane-iterations, compaction repack counts) as
machine-readable JSON so the perf trajectory is tracked across PRs —
the committed ``BENCH_run.json`` is the current quick-mode baseline, and
``benchmarks/perf_gate.py`` enforces it in CI.
"""
import argparse
import json
import sys
import time


def _peak_rss_mb() -> float:
    """High-water-mark resident set of this process, in MiB.

    ``ru_maxrss`` is KiB on Linux, bytes on macOS; 0.0 where the
    ``resource`` module is unavailable (non-POSIX).
    """
    try:
        import resource
        rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        if sys.platform == "darwin":
            rss //= 1024
        return rss / 1024.0
    except Exception:
        return 0.0


def _parse_row(row: str) -> dict:
    """'name,us,k=v;k=v' -> record dict (values floated where clean).

    Tolerant: some modules (roofline_table) emit non-numeric columns; keep
    the raw string rather than failing the module's whole record set.
    """
    name, us, derived = (row.split(",", 2) + ["", ""])[:3]
    try:
        us = float(us)
    except ValueError:
        pass
    rec = {"name": name, "us_per_call": us}
    metrics = {}
    for kv in derived.split(";"):
        if "=" not in kv:
            continue
        k, v = kv.split("=", 1)
        try:
            metrics[k] = float(v)
        except ValueError:
            metrics[k] = v
    rec["derived"] = metrics
    return rec


def _top_fns(fns: dict, k: int = 5) -> dict:
    """Slowest-compiling functions from a telemetry delta (bounded)."""
    ranked = sorted(fns.items(), key=lambda kv: -kv[1]["secs"])
    return {name: rec for name, rec in ranked[:k]}


def merge_only_doc(doc: dict, path: str) -> tuple[dict, str | None]:
    """Merge a ``--only`` run's doc into the baseline JSON at ``path``.

    A single-module run refreshes that module's entry INSIDE the existing
    baseline instead of replacing the whole document — the CI smoke jobs
    each run ``--only figNN --json BENCH_run.json`` and must not wipe the
    other modules' perf trajectory. ``total_wall_s`` becomes the sum of
    module walls (the only consistent meaning for a merged doc).

    Returns ``(doc_to_write, note)``; ``note`` is non-None when the
    baseline was unusable (corrupt/foreign) — the caller prints it so the
    CI log says loudly that the trajectory was overwritten, not silently.
    A missing baseline is the normal fresh-file case: no note.
    """
    try:
        with open(path) as f:
            prev = json.load(f)
        prev["modules"].update(doc["modules"])
        prev["total_wall_s"] = sum(
            m.get("wall_s", 0.0) for m in prev["modules"].values())
        return prev, None
    except FileNotFoundError:
        return doc, None        # fresh file: write this run alone
    except (OSError, ValueError, KeyError, TypeError, AttributeError) as e:
        return doc, f"merge_skipped={type(e).__name__}: {e}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results JSON")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from . import (analysis_gate, common, compaction_bench,
                   fig02_motivation, fig06_ablation, fig07_mix,
                   fig08_scalability, fig09_sync, fig10_abort_skew,
                   fig12_tpcc, fig13_batch, fig14_recovery, fig15_adaptive,
                   fig16_brook, fig17_serving, fig18_waitprofile,
                   fig19_hotspot, kernel_bench, profile_step,
                   roofline_table)
    from repro.obs import compile_log
    compile_log.enable_telemetry()
    modules = {
        "fig02": fig02_motivation, "fig06": fig06_ablation,
        "fig07": fig07_mix, "fig08": fig08_scalability,
        "fig09": fig09_sync, "fig10": fig10_abort_skew,
        "fig12": fig12_tpcc, "fig13": fig13_batch,
        "fig14": fig14_recovery, "fig15": fig15_adaptive,
        "fig16": fig16_brook, "fig17": fig17_serving,
        "fig18": fig18_waitprofile, "fig19": fig19_hotspot,
        "compaction": compaction_bench,
        "kernels": kernel_bench, "roofline": roofline_table,
        "profile": profile_step, "analysis": analysis_gate,
    }
    if args.only:
        modules = {args.only: modules[args.only]}
    # per-module compile counts depend on what ran before (cache entries
    # are created in run order) — the scope marker lets perf_gate.py
    # compare compile counts exactly only between like-scoped entries
    scope = f"only:{args.only}" if args.only else "suite"

    print("name,us_per_call,derived")
    doc = {"quick": quick, "modules": {}}
    t0 = time.time()
    for name, mod in modules.items():
        print(f"# --- {name} ---")
        sys.stdout.flush()
        tm = time.time()
        # compile accounting spans every jitted entry point (engine, aria,
        # traced runner, registered extras) — the sweep stats only see the
        # sweep substrate, so this is the whole-process truth per module
        tele0 = compile_log.snapshot()
        try:
            rows = mod.run(quick=quick) or []
        except Exception as e:  # keep the harness going
            print(f"{name}_ERROR,0,{type(e).__name__}:{e}")
            common.pop_sweep_stats()    # drop partial accounting
            tele = compile_log.delta(tele0)
            doc["modules"][name] = {
                "wall_s": time.time() - tm,
                "compiles": tele["compiles"],
                "compile_time_s": tele["compile_time_s"],
                "backend_compiles": tele["backend_compiles"],
                "peak_rss_mb": _peak_rss_mb(),
                "scope": scope,
                "error": f"{type(e).__name__}: {e}",
                "rows": [],
            }
            continue
        sweeps = common.pop_sweep_stats()
        tele = compile_log.delta(tele0)
        # per-module quick marker: a merged doc (--only into an existing
        # baseline, below) can mix modes, so the top-level flag alone
        # cannot be trusted for cross-commit comparisons
        doc["modules"][name] = {
            "wall_s": time.time() - tm,
            "quick": quick,
            "compiles": tele["compiles"],
            # wall seconds inside XLA backend compilation during this
            # module, and the slowest compiled functions it paid for —
            # the compile-time attack's per-module ledger
            "compile_time_s": tele["compile_time_s"],
            "backend_compiles": tele["backend_compiles"],
            "compiled_fns": _top_fns(tele["fns"]),
            # ru_maxrss is a process-lifetime high-water mark, so this is
            # monotone across modules in one run — compare same-position
            # or --only runs across commits, not adjacent modules
            "peak_rss_mb": _peak_rss_mb(),
            "scope": scope,
            "rows": [_parse_row(r) for r in rows],
            "sweeps": sweeps,
        }
        if sweeps:
            print(f"# {name}: {len(sweeps)} sweep(s), "
                  f"{sum(s['n_compiles'] for s in sweeps)} compile(s), "
                  f"{sum(s['lane_iters'] for s in sweeps)} lane-iters, "
                  f"{sum(s['n_repacks'] for s in sweeps)} repack(s), "
                  f"wall={doc['modules'][name]['wall_s']:.1f}s")
    doc["total_wall_s"] = time.time() - t0
    print(f"# total_wall_s={doc['total_wall_s']:.0f}")
    if args.json:
        out = doc
        if args.only:
            out, note = merge_only_doc(doc, args.json)
            if note:
                # corrupt/foreign baseline: overwriting loses the other
                # modules' trajectory — say so loudly in the output the
                # CI log keeps, rather than wiping it silently
                print(f"# {note}")
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
            f.write("\n")
        print(f"# json_written={args.json}")


if __name__ == "__main__":
    main()
