"""Benchmark aggregator: one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV. ``--full`` widens sweeps;
``--only fig08`` runs one module.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()
    quick = not args.full

    from . import (fig02_motivation, fig06_ablation, fig07_mix,
                   fig08_scalability, fig09_sync, fig10_abort_skew,
                   fig12_tpcc, fig13_batch, fig14_recovery, kernel_bench,
                   roofline_table)
    modules = {
        "fig02": fig02_motivation, "fig06": fig06_ablation,
        "fig07": fig07_mix, "fig08": fig08_scalability,
        "fig09": fig09_sync, "fig10": fig10_abort_skew,
        "fig12": fig12_tpcc, "fig13": fig13_batch,
        "fig14": fig14_recovery, "kernels": kernel_bench,
        "roofline": roofline_table,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, mod in modules.items():
        print(f"# --- {name} ---")
        sys.stdout.flush()
        try:
            mod.run(quick=quick)
        except Exception as e:  # keep the harness going
            print(f"{name}_ERROR,0,{type(e).__name__}:{e}")
    print(f"# total_wall_s={time.time() - t0:.0f}")


if __name__ == "__main__":
    main()
