"""Fig 9: synchronous vs asynchronous replication (FiT-calibrated costs:
the update chain includes the SQL layer ~50us; sync = 1ms network)."""
from .common import cc_point, emit
from repro.core.lock import WorkloadSpec, CostModel

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
PROTOS = ["mysql", "o2", "group", "bamboo", "aria"]


def run(quick=True):
    horizon = 2_000_000 if quick else 6_000_000
    rows = []
    for mode, lat in [("sync", 10_000), ("async", 1_000)]:
        cm = CostModel(op_exec=500, sync_lat=lat)
        base = None
        for p in PROTOS:
            row, r = cc_point(p, HOT, 256, horizon, costs=cm,
                              name=f"fig9_{mode}_{p}",
                              **({} if p == "aria" else
                                 dict(wait_timeout=2_000_000)))
            rows.append(row)
            if p == "mysql":
                base = r.tps
            if p == "group" and base:
                rows.append(f"fig9_{mode}_speedup,0,group_over_mysql="
                            f"{r.tps / max(base, 1):.1f}")
    return emit(rows)


if __name__ == "__main__":
    run()
