"""Fig 8: scalability in thread count, all protocols + Aria.

Sweep path: at each pow2 thread bucket the 5 lock protocols share one
engine compile and the Aria point its own (vs. one compile per point on
the seed's per-config loop); buckets reuse executables across figures."""
from .common import emit, sweep_rows
from repro.core.lock import WorkloadSpec
from repro.sweep import grid

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
PROTOS = ["mysql", "o1", "o2", "group", "bamboo", "aria"]


def run(quick=True):
    horizon = 200_000 if quick else 800_000
    threads = [1, 64, 256, 1024] if quick else [1, 16, 64, 128, 256, 512,
                                                1024]
    pts = grid(PROTOS, HOT, threads, horizon=horizon,
               name_fmt="fig8_{protocol}_T{n_threads}")
    rows, _ = sweep_rows(pts)
    return emit(rows)


if __name__ == "__main__":
    run()
