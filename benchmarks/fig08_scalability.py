"""Fig 8: scalability in thread count, all protocols + Aria."""
from .common import cc_point, emit
from repro.core.lock import WorkloadSpec

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
PROTOS = ["mysql", "o1", "o2", "group", "bamboo", "aria"]


def run(quick=True):
    horizon = 200_000 if quick else 800_000
    threads = [1, 64, 256, 1024] if quick else [1, 16, 64, 128, 256, 512,
                                                1024]
    rows = []
    for t in threads:
        for p in PROTOS:
            row, _ = cc_point(p, HOT, t, horizon, name=f"fig8_{p}_T{t}")
            rows.append(row)
    return emit(rows)


if __name__ == "__main__":
    run()
