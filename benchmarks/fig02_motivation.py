"""Fig 2: motivation. (a) MySQL hotspot update at high concurrency is
slower than serial execution (deadlock-detection cost grows with queue).
(b) queue locking's benefit shrinks as transaction latency grows."""
from .common import cc_point, emit
from repro.core.lock import WorkloadSpec, CostModel

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)


def run(quick=True):
    horizon = 200_000 if quick else 1_000_000
    rows = []
    # (a) mysql vs threads; serial reference first
    for t in ([1, 64, 256, 1024] if quick else [1, 16, 64, 256, 512, 1024]):
        row, _ = cc_point("mysql", HOT, t, horizon,
                          name=f"fig2a_mysql_T{t}")
        rows.append(row)
    # (b) o2 benefit vs txn latency (replication sync as latency proxy)
    for lat in [0, 2_000, 10_000]:
        cm = CostModel(sync_lat=lat)
        r1, a = cc_point("o2", HOT, 256, horizon, costs=cm,
                         name=f"fig2b_o2_lat{lat}")
        r2, b = cc_point("mysql", HOT, 256, horizon, costs=cm,
                         name=f"fig2b_mysql_lat{lat}")
        rows += [r1, r2,
                 f"fig2b_ratio_lat{lat},0,o2_over_mysql="
                 f"{a.tps / max(b.tps, 1):.2f}"]
    return emit(rows)


if __name__ == "__main__":
    run()
