"""Fig 2: motivation. (a) MySQL hotspot update at high concurrency is
slower than serial execution (deadlock-detection cost grows with queue).
(b) queue locking's benefit shrinks as transaction latency grows.

Runs on the sweep path: the whole figure is one (protocol × threads ×
sync-latency) grid over a single shape bucket — one engine compile.
"""
from .common import emit, sweep_rows
from repro.core.lock import WorkloadSpec, CostModel
from repro.sweep import grid

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)


def run(quick=True):
    horizon = 200_000 if quick else 1_000_000
    threads_a = [1, 64, 256, 1024] if quick else [1, 16, 64, 256, 512, 1024]
    lats = [0, 2_000, 10_000]

    # (a) mysql vs threads; serial reference first
    pts = grid("mysql", HOT, threads_a, horizon=horizon,
               name_fmt="fig2a_mysql_T{n_threads}")
    # (b) o2 benefit vs txn latency (replication sync as latency proxy)
    pts += grid(["o2", "mysql"], HOT, 256, horizon=horizon,
                costs=[CostModel(sync_lat=lat) for lat in lats],
                name_fmt="fig2b_{protocol}_lat{sync_lat}")

    rows, res = sweep_rows(pts)
    for lat in lats:
        a, b = res[f"fig2b_o2_lat{lat}"], res[f"fig2b_mysql_lat{lat}"]
        rows.append(f"fig2b_ratio_lat{lat},0,o2_over_mysql="
                    f"{a.tps / max(b.tps, 1):.2f}")
    return emit(rows)


if __name__ == "__main__":
    run()
