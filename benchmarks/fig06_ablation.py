"""Fig 6: ablation MySQL / O1 / O2 / TXSQL(group) on FiT + SysBench
workloads — throughput, p95 latency + lock-wait share, lock counts, CPU
utilization; hotspot vs uniform vs scan.

Sweep path: one grid, bucketed by workload shape (4 buckets — the two
uniform variants share a compile since write_ratio is traced)."""
from .common import emit, sweep_rows
from repro.core.lock import WorkloadSpec
from repro.sweep import grid

WORKLOADS = {
    "fit": WorkloadSpec(kind="fit", txn_len=2, n_rows=4096, n_hot=4),
    "hotspot": WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512),
    "scan": WorkloadSpec(kind="hotspot_scan", txn_len=10, n_rows=4096,
                         n_hot=4),
    "uniform_w": WorkloadSpec(kind="uniform", txn_len=4, n_rows=8192,
                              write_ratio=1.0),
    "uniform_r": WorkloadSpec(kind="uniform", txn_len=4, n_rows=8192,
                              write_ratio=0.0),
}

PROTOS = ["mysql", "o1", "o2", "group"]


def run(quick=True):
    horizon = 200_000 if quick else 800_000
    threads = [256] if quick else [64, 256, 1024]
    pts = grid(PROTOS, WORKLOADS, threads, horizon=horizon,
               name_fmt="fig6_{workload}_{protocol}_T{n_threads}")
    rows, _ = sweep_rows(pts)
    return emit(rows)


if __name__ == "__main__":
    run()
