"""Fig 6: ablation MySQL / O1 / O2 / TXSQL(group) on FiT + SysBench
workloads — throughput, p95 latency + lock-wait share, lock counts, CPU
utilization; hotspot vs uniform vs scan."""
from .common import cc_point, emit
from repro.core.lock import WorkloadSpec

FIT = WorkloadSpec(kind="fit", txn_len=2, n_rows=4096, n_hot=4)
HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
SCAN = WorkloadSpec(kind="hotspot_scan", txn_len=10, n_rows=4096, n_hot=4)
UNI_W = WorkloadSpec(kind="uniform", txn_len=4, n_rows=8192,
                     write_ratio=1.0)
UNI_R = WorkloadSpec(kind="uniform", txn_len=4, n_rows=8192,
                     write_ratio=0.0)

PROTOS = ["mysql", "o1", "o2", "group"]


def run(quick=True):
    horizon = 200_000 if quick else 800_000
    rows = []
    for wname, w in [("fit", FIT), ("hotspot", HOT), ("scan", SCAN),
                     ("uniform_w", UNI_W), ("uniform_r", UNI_R)]:
        threads = [256] if quick else [64, 256, 1024]
        for t in threads:
            for p in PROTOS:
                row, _ = cc_point(p, w, t, horizon,
                                  name=f"fig6_{wname}_{p}_T{t}")
                rows.append(row)
    return emit(rows)


if __name__ == "__main__":
    run()
