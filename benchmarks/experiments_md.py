"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts. (§Perf is hand-written — it is an iteration log.)"""
import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def _load():
    cells = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def dryrun_table():
    rows = ["| arch | shape | mesh | compile s | args GiB | temps GiB | "
            "out GiB | fallbacks |",
            "|---|---|---|---|---|---|---|---|"]
    gb = 1 << 30
    n_ok = n_err = 0
    for r in _load():
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR: {r['error'][:60]} | | | | |")
            n_err += 1
            continue
        n_ok += 1
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.0f} | "
            f"{(m['argument_bytes'] or 0) / gb:.2f} | "
            f"{(m['temp_bytes'] or 0) / gb:.2f} | "
            f"{(m['output_bytes'] or 0) / gb:.2f} | "
            f"{r['sharding_fallbacks']} |")
    rows.append(f"\n**{n_ok} cells compiled, {n_err} errors.**")
    return "\n".join(rows)


def roofline_table(mesh="16x16"):
    rows = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | "
            "bottleneck | useful | MFU bound | coll top |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in _load():
        if "error" in r or r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        br = roof.get("coll_breakdown", {})
        top = max(br, key=br.get) if br and max(br.values()) else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{roof['t_compute_s'] * 1e3:.2f} | "
            f"{roof['t_memory_s'] * 1e3:.2f} | "
            f"{roof['t_collective_s'] * 1e3:.2f} | "
            f"**{roof['bottleneck']}** | {roof['useful_ratio']:.2f} | "
            f"{roof['mfu_bound']:.2f} | {top} |")
    return "\n".join(rows)


def run(quick=True):
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table("16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table("2x16x16"))
    return []


if __name__ == "__main__":
    run()
