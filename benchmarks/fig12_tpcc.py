"""Fig 12: TPC-C-like workload, contention controlled by warehouse count."""
import dataclasses
from .common import cc_point, emit
from repro.core.lock import WorkloadSpec

PROTOS = ["mysql", "group", "bamboo", "aria"]


def run(quick=True):
    horizon = 200_000 if quick else 800_000
    rows = []
    for wh in ([1, 16] if quick else [1, 4, 16, 64]):
        w = WorkloadSpec(kind="tpcc", txn_len=10, n_rows=8192,
                         n_warehouses=wh, write_ratio=0.6)
        for p in PROTOS:
            row, _ = cc_point(p, w, 128, horizon,
                              name=f"fig12_{p}_wh{wh}")
            rows.append(row)
    return emit(rows)


if __name__ == "__main__":
    run()
