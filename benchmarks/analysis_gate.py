"""Analysis-subsystem benchmark module: lint + certifier wall cost.

Registers as ``analysis`` in benchmarks/run.py. The rows put the
correctness tooling itself on the perf trajectory: twice-lowering every
entry point is pure tracing (no XLA compile), so a jump in ``lint_*``
wall time means tracing got heavier — usually a new Python-level loop
in an entry point — and a jump in ``certify_*`` means the trace volume
per run grew. Derived columns carry the correctness telemetry
(findings, certified counts) so a regression in *what* was proven is as
loud as a slowdown.
"""
import time


def _row(name, wall_s, calls, **derived):
    us = (wall_s / max(calls, 1)) * 1e6
    kv = ";".join(f"{k}={v}" for k, v in derived.items())
    return f"{name},{us:.0f},{kv}"


def run(quick=True):
    from repro.analysis import cli as acli
    from repro.analysis import jaxpr_lint

    rows = []
    eps = jaxpr_lint.default_entry_points()
    if quick:
        keep = ("engine._run_dyn", "serving._hist_add",
                "kernels.segment_sums")
        eps = [e for e in eps if e.name in keep]
    t0 = time.time()
    findings = []
    for ep in eps:
        findings.extend(jaxpr_lint.lint_entry(ep))
    rows.append(_row("lint_entries", time.time() - t0, len(eps),
                     entries=len(eps), findings=len(findings)))

    t0 = time.time()
    lf = jaxpr_lint.lint_entry(jaxpr_lint.leaky_entry_point())
    caught = int(any(f.rule in ("value-leak", "static-leak") for f in lf))
    rows.append(_row("lint_leak_demo", time.time() - t0, 1,
                     caught=caught))

    kinds = ("zipf",) if quick else acli.KINDS
    seeds = (1,) if quick else acli.SEEDS
    t0 = time.time()
    certs = acli.run_certify_matrix(kinds=kinds, seeds=seeds,
                                    verbose=False)
    n_ok = sum(1 for _k, _s, c in certs if c.ok)
    rows.append(_row("certify_matrix", time.time() - t0, len(certs),
                     runs=len(certs), certified=n_ok,
                     committed=sum(c.n_committed for _k, _s, c in certs),
                     edges=sum(c.n_edges for _k, _s, c in certs)))
    return rows
