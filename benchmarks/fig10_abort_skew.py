"""Fig 10: (left) injected rollbacks -> cascading aborts for TXSQL/Bamboo;
(right) access skewness sweep (Zipf)."""
import dataclasses
from .common import cc_point, emit
from repro.core.lock import WorkloadSpec

HOTRW = WorkloadSpec(kind="hotspot_update", txn_len=4, n_rows=4096,
                     write_ratio=0.5)


def run(quick=True):
    horizon = 150_000 if quick else 600_000
    rows = []
    for pab in ([0.0, 0.05] if quick else [0.0, 0.01, 0.05, 0.1]):
        for p in ["group", "bamboo"]:
            row, r = cc_point(p, HOTRW, 128, horizon, p_abort=pab,
                              name=f"fig10a_{p}_inj{pab}")
            rows.append(row)
            rows.append(
                f"fig10a_{p}_inj{pab}_cascade,0,"
                f"amplification={r.forced_aborts / max(r.user_aborts, 1):.1f}")
    for sf in ([0.7, 0.99] if quick else [0.5, 0.7, 0.9, 0.99]):
        w = WorkloadSpec(kind="zipf", txn_len=1, n_rows=8192, zipf_s=sf)
        for p in ["mysql", "group", "bamboo", "aria"]:
            row, _ = cc_point(p, w, 256, horizon,
                              name=f"fig10b_{p}_sf{sf}")
            rows.append(row)
    return emit(rows)


if __name__ == "__main__":
    run()
