"""Fig 10: (left) injected rollbacks -> cascading aborts for TXSQL/Bamboo;
(right) access skewness sweep (Zipf).

Sweep path: the injection grid (protocol × p_abort) and the skew grid
(protocol × zipf_s, skew traced via the CDF table) each share one engine
compile; Aria skew points ride in their own bucket."""
from .common import emit, sweep_rows
from repro.core.lock import WorkloadSpec
from repro.sweep import expand, grid

HOTRW = WorkloadSpec(kind="hotspot_update", txn_len=4, n_rows=4096,
                     write_ratio=0.5)
ZIPF = WorkloadSpec(kind="zipf", txn_len=1, n_rows=8192)


def run(quick=True):
    horizon = 150_000 if quick else 600_000
    pabs = [0.0, 0.05] if quick else [0.0, 0.01, 0.05, 0.1]
    sfs = [0.7, 0.99] if quick else [0.5, 0.7, 0.9, 0.99]

    pts = grid(["group", "bamboo"], HOTRW, 128, horizon=horizon,
               p_abort=pabs, name_fmt="fig10a_{protocol}_inj{p_abort}")
    pts += grid(["mysql", "group", "bamboo", "aria"],
                expand(ZIPF, tag_fmt="sf{zipf_s}", zipf_s=sfs),
                256, horizon=horizon,
                name_fmt="fig10b_{protocol}_{workload}")

    rows, res = sweep_rows(pts)
    by_name = dict(zip((p.name for p in pts), rows))
    out = []
    for p in pts:
        out.append(by_name[p.name])
        if p.name.startswith("fig10a"):
            r = res[p.name]
            out.append(
                f"{p.name}_cascade,0,"
                f"amplification={r.forced_aborts / max(r.user_aborts, 1):.1f}")
    return emit(out)


if __name__ == "__main__":
    run()
