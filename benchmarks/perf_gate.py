"""CI perf-regression gate: diff a fresh BENCH_run.json vs the baseline.

The committed ``BENCH_run.json`` is the repo's perf trajectory; this
gate makes it self-enforcing. A fresh quick run (usually one smoke job's
``--only figNN``) is compared per module against the committed baseline:

* **wall**: fail when ``fresh > baseline * wall_ratio + wall_slack_s``.
  The default (1.5x + 5s) is deliberately loose — CI runners are shared
  and 1-core; the gate exists to catch 2x-class regressions (a recompile
  in a loop, an accidental un-vmapped sweep), not 10% noise. Speedups
  never fail; they're reported so the baseline gets re-committed.
* **compiles**: exact equality, but only between entries with the same
  ``scope`` marker (compile counts depend on what ran earlier in the
  process — a ``--only`` run and a full-suite run see different caches).
  A compile-count increase at equal scope is exactly the "param promoted
  into the compile key" regression this repo keeps hunting.
* **errors**: a fresh module entry carrying ``error`` always fails.
* **coverage**: modules only in the fresh doc are allowed (new
  benchmarks); modules only in the baseline are noted, not failed (smoke
  jobs legitimately run subsets) — unless ``--modules`` names them.
* **quick/full**: wall is only compared between like modes.

``compile_time_s`` deltas are reported (the compile-time attack's
ledger) but never gate — backend compile wall is too host-dependent.

Re-baselining: when a slowdown is real and accepted (new feature, wider
coverage), re-run ``python -m benchmarks.run --only MOD --json
BENCH_run.json`` and commit the refreshed file — the PR diff then shows
the regression as a reviewed number instead of a silent drift
(DESIGN.md §12). For *speedups* the gate now closes its own loop:
``--update-baseline`` rewrites the baseline entries of exactly the
modules the compare flagged with a speedup note, from the fresh doc —
never touching regressed, errored, or mode-mismatched modules — so
"consider re-baselining" becomes a reviewable file change instead of a
note that rots in a CI log.

Exit code 0 = gate passed; 1 = regression/failure; 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import sys

WALL_RATIO = 1.5
WALL_SLACK_S = 5.0


def _fmt(x: float) -> str:
    return f"{x:.1f}"


def compare(baseline: dict, fresh: dict, *, wall_ratio: float = WALL_RATIO,
            wall_slack_s: float = WALL_SLACK_S, modules=None,
            compile_exact: bool = True) -> tuple[bool, list[str]]:
    """Gate ``fresh`` against ``baseline``. Returns (ok, report_lines).

    ``modules``: optional iterable restricting which module names gate
    (others still get informational lines). Every failure line starts
    with ``FAIL``; the gate fails iff any does.
    """
    want = set(modules) if modules else None
    base_mods = baseline.get("modules", {})
    fresh_mods = fresh.get("modules", {})
    lines: list[str] = []
    ok = True

    def fail(msg: str) -> None:
        nonlocal ok
        ok = False
        lines.append(f"FAIL {msg}")

    names = sorted(set(base_mods) | set(fresh_mods))
    for name in names:
        gated = want is None or name in want
        b, f = base_mods.get(name), fresh_mods.get(name)
        if f is None:
            if want and name in want:
                fail(f"{name}: requested module missing from fresh run")
            else:
                lines.append(f"note {name}: not in fresh run (subset ok)")
            continue
        if b is None:
            lines.append(f"note {name}: new module (no baseline) "
                         f"wall={_fmt(f.get('wall_s', 0.0))}s")
            continue
        if f.get("error"):
            (fail if gated else lines.append)(
                f"{name}: fresh run errored: {f['error']}")
            continue
        if b.get("error"):
            lines.append(f"note {name}: baseline errored; skipping compare")
            continue

        bw, fw = b.get("wall_s", 0.0), f.get("wall_s", 0.0)
        if b.get("quick") != f.get("quick"):
            lines.append(f"note {name}: quick/full mode mismatch; "
                         f"wall not compared")
        else:
            limit = bw * wall_ratio + wall_slack_s
            if fw > limit and gated:
                fail(f"{name}: wall {_fmt(fw)}s > limit {_fmt(limit)}s "
                     f"(baseline {_fmt(bw)}s x{wall_ratio} + "
                     f"{_fmt(wall_slack_s)}s)")
            elif fw < bw / wall_ratio - wall_slack_s:
                lines.append(f"note {name}: speedup {_fmt(bw)}s -> "
                             f"{_fmt(fw)}s — consider re-baselining")
            else:
                lines.append(f"ok   {name}: wall {_fmt(fw)}s "
                             f"(baseline {_fmt(bw)}s)")

        bc, fc = b.get("compiles"), f.get("compiles")
        same_scope = b.get("scope") is not None \
            and b.get("scope") == f.get("scope") \
            and b.get("quick") == f.get("quick")
        if not compile_exact or bc is None or fc is None:
            pass
        elif not same_scope:
            why = "no scope marker in baseline" if b.get("scope") is None \
                else f"scope mismatch ({b.get('scope')} vs {f.get('scope')})"
            lines.append(f"note {name}: {why}; compile count not compared")
        elif fc != bc:
            (fail if gated else lines.append)(
                f"{name}: compiles {fc} != baseline {bc} "
                f"(recompile regression?)")
        bt, ft = b.get("compile_time_s"), f.get("compile_time_s")
        if bt is not None and ft is not None:
            lines.append(f"info {name}: compile_time_s "
                         f"{_fmt(ft)} (baseline {_fmt(bt)})")
    lines.append("gate: " + ("PASS" if ok else "FAIL"))
    return ok, lines


def speedup_modules(baseline: dict, fresh: dict, *,
                    wall_ratio: float = WALL_RATIO,
                    wall_slack_s: float = WALL_SLACK_S) -> list[str]:
    """Module names ``compare`` flags with a speedup note: present in
    both docs, neither errored, same quick/full mode, and fresh wall
    under ``baseline / wall_ratio - wall_slack_s``."""
    out = []
    base_mods = baseline.get("modules", {})
    for name, f in fresh.get("modules", {}).items():
        b = base_mods.get(name)
        if b is None or f.get("error") or b.get("error"):
            continue
        if b.get("quick") != f.get("quick"):
            continue
        if f.get("wall_s", 0.0) < \
                b.get("wall_s", 0.0) / wall_ratio - wall_slack_s:
            out.append(name)
    return sorted(out)


def update_baseline(baseline: dict, fresh: dict, names) -> dict:
    """New baseline doc with ``names``' module entries replaced by the
    fresh ones. ``total_wall_s`` is recomputed from the merged modules;
    top-level flags stay the baseline's (the merged doc can mix modes —
    per-module ``quick`` markers carry the truth, as in merge_only_doc)."""
    out = dict(baseline)
    out["modules"] = dict(baseline.get("modules", {}))
    fresh_mods = fresh.get("modules", {})
    for name in names:
        out["modules"][name] = fresh_mods[name]
    out["total_wall_s"] = sum(
        m.get("wall_s", 0.0) for m in out["modules"].values())
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_run.json")
    ap.add_argument("--fresh", required=True,
                    help="BENCH_run.json from this run")
    ap.add_argument("--modules", default=None,
                    help="comma-separated module names to gate "
                         "(others informational)")
    ap.add_argument("--wall-ratio", type=float, default=WALL_RATIO)
    ap.add_argument("--wall-slack", type=float, default=WALL_SLACK_S)
    ap.add_argument("--no-compile-exact", action="store_true",
                    help="skip the exact compile-count check")
    ap.add_argument("--report", default=None,
                    help="also write the report to this path (CI artifact)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite speedup-flagged modules' baseline "
                         "entries from the fresh doc (in place)")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as fp:
            baseline = json.load(fp)
        with open(args.fresh) as fp:
            fresh = json.load(fp)
    except (OSError, ValueError) as e:
        print(f"perf_gate: cannot load inputs: {e}", file=sys.stderr)
        return 2

    mods = [m for m in (args.modules or "").split(",") if m] or None
    ok, lines = compare(baseline, fresh, wall_ratio=args.wall_ratio,
                        wall_slack_s=args.wall_slack, modules=mods,
                        compile_exact=not args.no_compile_exact)
    report = "\n".join(lines)
    print(report)
    if args.report:
        with open(args.report, "w") as fp:
            fp.write(report + "\n")
    if args.update_baseline:
        names = speedup_modules(baseline, fresh,
                                wall_ratio=args.wall_ratio,
                                wall_slack_s=args.wall_slack)
        if names:
            doc = update_baseline(baseline, fresh, names)
            with open(args.baseline, "w") as fp:
                json.dump(doc, fp, indent=1)
                fp.write("\n")
            print(f"baseline updated for speedups: {', '.join(names)} "
                  f"-> {args.baseline}")
        else:
            print("no speedup-flagged modules; baseline unchanged")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
