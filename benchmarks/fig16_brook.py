"""Fig 16 (extension): Brook-2PL vs mysql-2PL / bamboo / group.

Two grids on the sweep substrate (one engine compile per shape bucket,
brook2pl riding the same ``DynParams`` flags as every other protocol):

* ``fig16a`` — zipf skew ramp: multi-row write transactions over a Zipf
  key space, skew axis. This is the deadlock regime: mysql pays the
  detection walk (``dd_coeff * queue``) on every grant and rolls victims
  back; detection-free queue protocols stall outright. Brook-2PL's
  chop-ordered acquisition makes waits-for cycles impossible (zero
  detection ticks, zero deadlock rollbacks) and per-op release holds hot
  rows only ``[acquire, last-use]``.
* ``fig16b`` — TPC-C-like warehouse sweep: contention via warehouse
  count; the chop analysis orders stock < district < warehouse so the
  hottest (warehouse) lock is taken last and released first.

Emits an ``fig16_adv`` row per grid with the brook-vs-mysql commit ratio
at the most contended point plus brook's summed deadlock-detection ticks
and deadlock (forced) rollbacks — the quick-mode acceptance is
``brook_vs_mysql > 1`` on the high-skew zipf points with both counters
at zero (asserted by the CI ``brook-smoke`` job).
"""
from .common import emit, sweep_rows
from repro.core.lock import WorkloadSpec
from repro.sweep import expand, grid

ZIPF = WorkloadSpec(kind="zipf", txn_len=4, n_rows=4096)
TPCC = WorkloadSpec(kind="tpcc", txn_len=10, n_rows=8192, write_ratio=0.6)
PROTOS = ["mysql", "bamboo", "group", "brook2pl"]


def _adv_row(tag, res, names_by_proto, at):
    """brook-vs-mysql ratio at the most contended axis point ``at``."""
    brook = res[names_by_proto["brook2pl"][at]]
    mysql = res[names_by_proto["mysql"][at]]
    dd = sum(res[n].dd_ticks for n in names_by_proto["brook2pl"])
    fa = sum(res[n].forced_aborts for n in names_by_proto["brook2pl"])
    return (f"fig16_{tag}_adv,0,"
            f"brook_vs_mysql={brook.commits / max(mysql.commits, 1):.3f}"
            f";brook_dd_ticks={dd};brook_deadlock_aborts={fa}")


def run(quick=True):
    horizon = 150_000 if quick else 600_000
    sfs = [0.6, 0.9, 1.2] if quick else [0.3, 0.6, 0.8, 0.9, 1.1, 1.3]
    whs = [1, 8] if quick else [1, 4, 16, 64]

    pts = grid(PROTOS, expand(ZIPF, tag_fmt="sf{zipf_s}", zipf_s=sfs),
               64, horizon=horizon,
               name_fmt="fig16a_{protocol}_{workload}")
    pts += grid(PROTOS,
                expand(TPCC, tag_fmt="wh{n_warehouses}",
                       n_warehouses=whs),
                128, horizon=horizon,
                name_fmt="fig16b_{protocol}_{workload}")
    rows, res = sweep_rows(pts)

    out = list(rows)
    a_names = {p: [f"fig16a_{p}_sf{s}" for s in sfs] for p in PROTOS}
    b_names = {p: [f"fig16b_{p}_wh{w}" for w in whs] for p in PROTOS}
    out.append(_adv_row("zipf", res, a_names, at=len(sfs) - 1))
    out.append(_adv_row("tpcc", res, b_names, at=0))
    return emit(out)


if __name__ == "__main__":
    run()
