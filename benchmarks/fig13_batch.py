"""Fig 13: group-lock batch size (fixed vs dynamic close), group commit in
sync/async replication, fixed-TPS arrival latency effect (§4.6.1)."""
import dataclasses
from .common import cc_point, emit
from repro.core.lock import WorkloadSpec, CostModel

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)


def run(quick=True):
    horizon = 400_000 if quick else 1_500_000
    rows = []
    # batch size sweep at high + low concurrency
    for t in [32, 512]:
        for b in ([1, 10, 64] if quick else [1, 4, 10, 32, 64, 256]):
            row, _ = cc_point("group", HOT, t, horizon, batch_size=b,
                              dynamic_batch=False,
                              name=f"fig13a_B{b}_T{t}")
            rows.append(row)
    # dynamic vs fixed batch under a fixed-TPS (open-loop) arrival model
    cm = CostModel(arrival_rate=2.0)          # 2 txsqueued/tick = 20k TPS
    for mode, dyn in [("fixed", False), ("dynamic", True)]:
        row, r = cc_point("group", HOT, 64, horizon, costs=cm,
                          batch_size=32, dynamic_batch=dyn,
                          name=f"fig13b_{mode}")
        rows.append(row)
    # group commit on/off, sync vs async
    for mode, lat in [("sync", 10_000), ("async", 1_000)]:
        cm = CostModel(op_exec=500, sync_lat=lat)
        for gc in (True, False):
            row, _ = cc_point("group", HOT, 512, horizon * 3, costs=cm,
                              group_commit=gc,
                              name=f"fig13c_{mode}_gc{int(gc)}")
            rows.append(row)
    return emit(rows)


if __name__ == "__main__":
    run()
