"""Kernel-level schedule comparison (CPU wall-clock is a schedule proxy,
not a TPU claim): hotspot-grouped scatter-apply vs XLA's serialized
duplicate-index scatter, under Zipf duplication; flash-attention kernel
interpret sanity timing."""
import time

import numpy as np
import jax
import jax.numpy as jnp

from .common import emit
from repro.core.group_apply import group_apply, scatter_serial
from repro.core.lock.workload import zipf_cdf


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick=True):
    rows = []
    rng = np.random.default_rng(0)
    V, D = 50_000, 512
    N = 32_768 if quick else 262_144
    table = jnp.zeros((V, D), jnp.float32)
    upd = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    cdf = zipf_cdf(V, 1.2)
    for skew, name in [(None, "uniform"), (cdf, "zipf1.2")]:
        if skew is None:
            ids = rng.integers(0, V, N)
        else:
            ids = np.searchsorted(skew, rng.random(N))
        ids = jnp.asarray(ids.astype(np.int32))
        f_serial = jax.jit(scatter_serial)
        f_group = jax.jit(group_apply)
        t_ser = _time(f_serial, table, ids, upd)
        t_grp = _time(f_group, table, ids, upd)
        dup = N / len(np.unique(np.asarray(ids)))
        rows.append(f"kernel_scatter_serial_{name},{t_ser:.0f},dup={dup:.1f}")
        rows.append(f"kernel_scatter_grouped_{name},{t_grp:.0f},"
                    f"speedup={t_ser / t_grp:.2f}")
    return emit(rows)


if __name__ == "__main__":
    run()
