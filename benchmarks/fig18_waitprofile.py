"""Fig 18: wait-profile — where every thread-tick goes, per protocol.

The paper's argument in one table: on the fig2 hotspot, mysql burns its
ticks on lock-wait plus deadlock-detection scans, o2's early release
converts wait into exec, group commit trades some exec for commit-wait
amortization, and brook2pl removes detection entirely (ordered acquire
is deadlock-free). Rows carry TickBreakdown *fractions* (sum ≈ 1 over
exec/lock_wait/commit_wait/rollback/detection/sync/idle), straight from
the engine's on-device accumulator — no sampling, no host probes.

A final traced row profiles mysql on a deadlock-prone zipf workload
through the event buffer (``simulate_traced``): wait spans, victims,
drop accounting, and the blame-matrix reduction of the same events
(wait time paired with the holding transaction attempt, DESIGN.md §14)
— the same data ``examples/trace_quickstart.py`` renders as a blame
table and exports to Perfetto.
"""
import time

import numpy as np

from .common import emit
from repro.core.lock import WorkloadSpec, simulate, extract
from repro.obs import (blame_matrix, check_conservation, fractions,
                       simulate_traced, events_host, EV_WAIT_ENTER,
                       EV_VICTIM, EV_GRANT, EV_TIMEOUT)

HOT = WorkloadSpec(kind="hotspot_update", txn_len=1, n_rows=512)
ZIPF = WorkloadSpec(kind="zipf", txn_len=4, n_rows=2048, zipf_s=0.9)
PROTOCOLS = ("mysql", "o2", "group", "brook2pl")


def _frac_row(name: str, wall_us: float, bd: dict) -> str:
    fr = fractions(bd)
    body = ";".join(f"{k}={v:.4f}" for k, v in fr.items())
    return f"{name},{wall_us:.1f},{body}"


def run(quick=True):
    horizon = 150_000 if quick else 1_000_000
    threads = 256
    rows = []

    # (a) attribution fractions on the fig2 hotspot, one row per protocol
    for proto in PROTOCOLS:
        t0 = time.perf_counter()
        s = simulate(proto, HOT, n_threads=threads, horizon=horizon)
        r = extract(proto, threads, s)
        wall_us = (time.perf_counter() - t0) * 1e6
        check_conservation(s, int(s.th.phase.shape[0]))
        rows.append(_frac_row(f"fig18_{proto}", wall_us, r.breakdown))

    # (b) event-trace profile: mysql under deadlock-prone zipf contention
    t0 = time.perf_counter()
    horizon_tr = 120_000 if quick else 500_000
    s, tb = simulate_traced("mysql", ZIPF, n_threads=64,
                            horizon=horizon_tr, cap=65_536)
    wall_us = (time.perf_counter() - t0) * 1e6
    ev = events_host(tb)
    n = int(ev["n"])
    counts = np.bincount(ev["ev"], minlength=8)
    rows.append(
        f"fig18_profile_mysql,{wall_us:.1f},"
        f"events={n};dropped={int(ev['dropped'])};"
        f"wait_enter={int(counts[EV_WAIT_ENTER])};"
        f"grant={int(counts[EV_GRANT])};"
        f"timeout={int(counts[EV_TIMEOUT])};"
        f"deadlock_victim={int(counts[EV_VICTIM])}")

    # (c) blame reduction of the same capture: how much of the queued
    # time has a recorded holder, and how concentrated the blockers are
    t0 = time.perf_counter()
    b = blame_matrix(ev, end=int(s.g.now))
    wall_us = (time.perf_counter() - t0) * 1e6
    top = b.top_blockers(1)
    rows.append(
        f"fig18_blame_mysql,{wall_us:.1f},"
        f"spans={b.n_spans};queued_ticks={b.total_wait};"
        f"blocked_rows={len(b.per_record)};"
        f"blockers={len(b.per_txn)};"
        f"top_blocker_ticks={top[0][1] if top else 0}")
    return emit(rows)


if __name__ == "__main__":
    run()
