"""Compaction benchmark: mixed-density grid, sort-then-cut vs compacted.

The grid pairs the protocols that churn on contended multi-row zipf
(mysql/o1 keep committing via deadlock detection) with the ones that
deadlock-stall without detection (o2/group at T>=16 sit idle at tens of
iterations) — a density mix the analytic iteration estimate cannot see,
so the PR-1 sort-then-cut chunking locksteps 10k-iteration lanes with
near-idle ones. Rows report wall and the modeled vmapped cost
(``lane_iters`` = width x slowest-lane iterations summed over device
calls) for both paths at the same forced vmap width; the acceptance bar
is compaction cutting lane_iters >= 2x (asserted in tests; measured
here for BENCH_run.json).
"""
from .common import emit, sweep_rows
from repro.core.lock import WorkloadSpec
from repro.sweep import point

ZIPF = WorkloadSpec(kind="zipf", txn_len=2, n_rows=512, zipf_s=0.9)
CHUNK = 8


def _grid(horizon):
    """One full chunk whose composition sort-then-cut CANNOT fix: two
    churning lanes and six stalled ones share the pack (there is only one
    chunk to cut), so the chunked path pays 8 x the churning lanes'
    iterations while compaction retires the stalled lanes on call 1."""
    mk = lambda pr, t: point(pr, ZIPF, t, horizon=horizon,
                             name=f"cmp_{pr}_T{t}")
    return [mk("o1", 16), mk("mysql", 16),
            mk("o2", 16), mk("o2", 32), mk("o2", 64),
            mk("group", 16), mk("group", 32), mk("group", 64)]


def run(quick=True):
    horizon = 100_000 if quick else 400_000
    rows = []
    for tag, compact in (("off", False), ("on", True)):
        _, res = sweep_rows(_grid(horizon), chunk_size=CHUNK,
                            compact=compact)
        rows.append(
            f"compaction_{tag},{res.wall_s * 1e6 / len(res.points):.0f},"
            f"lane_iters={res.lane_iters};n_repacks={res.n_repacks};"
            f"n_calls={sum(b.n_chunks for b in res.buckets)};"
            f"n_compiles={res.n_compiles};wall_s={res.wall_s:.3f}")
    return emit(rows)


if __name__ == "__main__":
    run()
