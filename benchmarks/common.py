"""Shared benchmark plumbing. Every figure module exposes
``run(quick=True) -> list[str]`` of CSV rows ``name,us_per_call,derived``.

Two measurement paths:
  * ``cc_point``  — one config, one ``simulate()`` call (legacy / odd
    one-off points).
  * ``sweep_rows`` — a whole grid through ``repro.sweep`` (one compile per
    shape bucket, vmapped lanes); ``us_per_call`` is the per-point
    amortized wall time of the batched execution.
"""
from __future__ import annotations

import os
import sys
import time


def enable_compile_cache() -> str | None:
    """Point JAX's persistent compilation cache at ``$REPRO_COMPILE_CACHE``.

    Opt-in and best-effort: unset env -> no-op, and any failure to enable
    (old jax, read-only dir) degrades to cold compiles rather than
    breaking the benchmark run. Returns the cache dir when enabled. CI
    smoke jobs set the env so repeat runs skip XLA compilation entirely.
    """
    d = os.environ.get("REPRO_COMPILE_CACHE")
    if not d:
        return None
    try:
        import jax
        os.makedirs(d, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", d)
        # default threshold skips sub-second compiles; the engine's small
        # shape buckets are exactly those, so cache everything
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        return d
    except Exception:
        return None


enable_compile_cache()

from repro.core.lock import (simulate, extract, simulate_aria, extract_aria,
                             WorkloadSpec, CostModel)
from repro.core.lock.metrics import bench_row
from repro.sweep import run_sweep, summarize


def cc_point(proto, workload, threads, horizon, costs=None, name=None,
             **kw):
    """One CC-engine measurement -> (csv_row, SimResult)."""
    t0 = time.perf_counter()
    if proto == "aria":
        s = simulate_aria(workload, threads, costs=costs, horizon=horizon)
        r = extract_aria(threads, s)
    else:
        s = simulate(proto, workload, n_threads=threads, horizon=horizon,
                     costs=costs, **kw)
        r = extract(proto, threads, s)
    wall_us = (time.perf_counter() - t0) * 1e6
    return bench_row(name or f"{proto}_T{threads}", wall_us, r), r


# Per-module sweep accounting: every sweep_rows() call appends a stats
# record here; benchmarks/run.py pops them into the module's JSON entry so
# the perf trajectory (BENCH_run.json) tracks compiles, wall, and the
# compaction scheduler's repack counts across PRs.
_SWEEP_STATS: list[dict] = []


def sweep_stats(res) -> dict:
    return {
        "n_points": len(res.points),
        "n_compiles": res.n_compiles,
        "wall_s": res.wall_s,
        "lane_iters": res.lane_iters,
        "n_repacks": res.n_repacks,
        "n_calls": sum(b.n_chunks for b in res.buckets),
        "compacted": any(b.compacted for b in res.buckets),
    }


def pop_sweep_stats() -> list[dict]:
    out, _SWEEP_STATS[:] = list(_SWEEP_STATS), []
    return out


def sweep_rows(points, names=None, **sweep_kw):
    """Run a grid through the sweep subsystem -> (csv_rows, SweepResults)."""
    res = run_sweep(points, **sweep_kw)
    _SWEEP_STATS.append(sweep_stats(res))
    return summarize(res, names), res


def emit(rows):
    for r in rows:
        print(r)
    sys.stdout.flush()
    return rows
