"""Shared benchmark plumbing. Every figure module exposes
``run(quick=True) -> list[str]`` of CSV rows ``name,us_per_call,derived``."""
from __future__ import annotations

import sys
import time

from repro.core.lock import (simulate, extract, simulate_aria, extract_aria,
                             WorkloadSpec, CostModel)


def cc_point(proto, workload, threads, horizon, costs=None, name=None,
             **kw):
    """One CC-engine measurement -> (csv_row, SimResult)."""
    t0 = time.perf_counter()
    if proto == "aria":
        s = simulate_aria(workload, threads, costs=costs, horizon=horizon)
        r = extract_aria(threads, s)
    else:
        s = simulate(proto, workload, n_threads=threads, horizon=horizon,
                     costs=costs, **kw)
        r = extract(proto, threads, s)
    wall_us = (time.perf_counter() - t0) * 1e6
    nm = name or f"{proto}_T{threads}"
    row = (f"{nm},{wall_us:.0f},tps={r.tps:.0f};p95us={r.p95_latency_us:.0f}"
           f";abort={r.abort_rate:.3f};lockops={r.lock_ops}"
           f";cpu={r.cpu_util:.2f};waitfrac={r.lock_wait_frac:.2f}")
    return row, r


def emit(rows):
    for r in rows:
        print(r)
    sys.stdout.flush()
    return rows
