"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")

HEADER = ("arch,shape,mesh,bottleneck,t_compute_ms,t_memory_ms,"
          "t_collective_ms,useful_ratio,mfu_bound,args_gib,temps_gib")


def rows(mesh_filter=None):
    out = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        r = json.load(open(f))
        if "error" in r:
            out.append(f"{r['arch']},{r['shape']},{r['mesh']},"
                       f"ERROR,,,,,,,")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        roof = r["roofline"]
        gb = 1 << 30
        out.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{roof['bottleneck']},"
            f"{roof['t_compute_s'] * 1e3:.2f},"
            f"{roof['t_memory_s'] * 1e3:.2f},"
            f"{roof['t_collective_s'] * 1e3:.2f},"
            f"{roof['useful_ratio']:.3f},{roof['mfu_bound']:.3f},"
            f"{(r['memory']['argument_bytes'] or 0) / gb:.2f},"
            f"{(r['memory']['temp_bytes'] or 0) / gb:.2f}")
    return out


def run(quick=True):
    out = [HEADER] + rows()
    for r in out:
        print(r)
    return out


if __name__ == "__main__":
    run()
