"""Roofline tables: dry-run artifacts + AOT-compiled engine entry points.

Part 1 (``rows``) renders the launch dry-run artifacts under
``experiments/dryrun`` (EXPERIMENTS.md §Roofline). Part 2
(``engine_rows``) is the engine-side roofline this repo actually needs:
AOT-compile ``_run_dyn``/``_run_batch``/``_run_seg_batch`` per
(protocol, T, L), pull FLOPs / bytes-accessed from
``compiled.cost_analysis()`` — the ``lax.while_loop`` body is counted
once, so the numbers are ≈ per engine iteration — and place each
executable against the ``launch/roofline.py`` hardware model
(``dist_to_peak`` = bound-time / compute-time; large = memory-bound).

Caveat (DESIGN.md §12): the hardware model is the TPU-v5e-like chip from
``launch/roofline.py``; on the CPU hosts that run this table the
absolute times are hypothetical — the *ratios* (arithmetic intensity,
bottleneck, per-entry-point growth with T and L) are the signal, and the
point of the table is that every engine entry point sits deep in the
memory-bound regime: the future Pallas kernel's job is fusing the T×L
scans, not adding FLOPs.
"""
import glob
import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")

HEADER = ("arch,shape,mesh,bottleneck,t_compute_ms,t_memory_ms,"
          "t_collective_ms,useful_ratio,mfu_bound,args_gib,temps_gib")

ENGINE_HEADER = ("name,t_bound_us,flops;bytes;ai;bottleneck;dist_to_peak;"
                 "coll_bytes;hlo_kb;compile_s")


def rows(mesh_filter=None, out_dir=None):
    """Dry-run artifact rows; ``mesh_filter`` applies to EVERY row,
    error artifacts included (they carry a mesh too)."""
    out = []
    for f in sorted(glob.glob(os.path.join(out_dir or OUT_DIR, "*.json"))):
        r = json.load(open(f))
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        if "error" in r:
            out.append(f"{r['arch']},{r['shape']},{r['mesh']},"
                       f"ERROR,,,,,,,")
            continue
        roof = r["roofline"]
        gb = 1 << 30
        out.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{roof['bottleneck']},"
            f"{roof['t_compute_s'] * 1e3:.2f},"
            f"{roof['t_memory_s'] * 1e3:.2f},"
            f"{roof['t_collective_s'] * 1e3:.2f},"
            f"{roof['useful_ratio']:.3f},{roof['mfu_bound']:.3f},"
            f"{(r['memory']['argument_bytes'] or 0) / gb:.2f},"
            f"{(r['memory']['temp_bytes'] or 0) / gb:.2f}")
    return out


def _cost_totals(compiled) -> tuple[float, float]:
    """(flops, bytes_accessed) from ``cost_analysis`` (dict or [dict])."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _stack(tree, g: int):
    import jax
    import jax.numpy as jnp
    return jax.tree.map(lambda x: jnp.stack([x] * g), tree)


def engine_rows(quick=True):
    """AOT roofline rows for the engine entry points, per (protocol,T,L)."""
    import jax.numpy as jnp

    from repro.core.lock import (CostModel, EngineConfig, WorkloadSpec,
                                 protocol_params, split_config,
                                 init_state_dyn)
    from repro.core.lock import engine as E
    from repro.launch.roofline import PEAK_FLOPS, HBM_BW, collective_bytes
    from repro.obs import compile_log

    grid = [("mysql", 64, 4), ("brook2pl", 64, 4)]
    if not quick:
        grid += [("mysql", 256, 4), ("group", 256, 4), ("brook2pl", 256, 4)]
    G = 4                       # lanes for the batched entry points

    out = []
    for proto, T, L in grid:
        cfg = EngineConfig(
            protocol=protocol_params(proto), costs=CostModel(),
            workload=WorkloadSpec(kind="hotspot_update", txn_len=L,
                                  n_rows=512),
            n_threads=T, horizon=200_000)
        stat, dp = split_config(cfg)
        s0 = init_state_dyn(stat, dp)
        until = jnp.asarray(100_000, jnp.int32)
        entries = [("run_dyn", E._run_dyn, (stat, dp, s0))]
        # batched + segmented entry points: mysql always; the rest of the
        # grid only in full mode (each AOT compile is seconds on 1 core)
        if proto == "mysql" or not quick:
            entries += [
                ("run_batch", E._run_batch,
                 (stat, _stack(dp, G), _stack(s0, G))),
                ("run_seg_batch", E._run_seg_batch,
                 (stat, _stack(dp, G), _stack(s0, G), _stack(until, G))),
            ]
        for ename, fn, fargs in entries:
            t0 = time.perf_counter()
            compiled = fn.lower(*fargs).compile()
            compile_s = time.perf_counter() - t0
            flops, byts = _cost_totals(compiled)
            hlo = compiled.as_text()
            coll = sum(collective_bytes(hlo).values())
            t_c = flops / PEAK_FLOPS
            t_m = byts / HBM_BW
            t_bound = max(t_c, t_m)
            bottleneck = "compute" if t_c >= t_m else "memory"
            dist = (t_bound / t_c) if t_c > 0 else float("inf")
            out.append(
                f"roofline_engine_{ename}_{proto}_T{T}xL{L},"
                f"{t_bound * 1e6:.4f},"
                f"flops={flops:.0f};bytes={byts:.0f};"
                f"ai={flops / byts if byts else 0.0:.4f};"
                f"bottleneck={bottleneck};"
                f"dist_to_peak={dist if dist != float('inf') else -1:.1f};"
                f"coll_bytes={coll};"
                f"hlo_kb={compile_log.hlo_module_bytes(compiled) / 1024:.1f};"
                f"compile_s={compile_s:.2f}")
    return out


def run(quick=True):
    out = [HEADER] + rows()
    out.append(ENGINE_HEADER)
    out += engine_rows(quick=quick)
    for r in out:
        print(r)
    return out


if __name__ == "__main__":
    run()
