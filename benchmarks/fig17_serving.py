"""Fig 17 (extension): open-system serving — knee curves and tail latency.

TXSQL's "high-contented workloads" claim is about *serving* traffic, so
this figure replaces commits-per-horizon with the open-system view: each
protocol serves Poisson arrivals through a bounded engine pool
(``repro.serving``) at a ladder of offered loads, and we read off

* the **knee curve** — delivered goodput vs offered load flattens at the
  protocol's contended capacity (the knee), which sits far below the
  uncontended M/M/c capacity on a hotspot workload and at a different
  place per protocol;
* **tail latency** — p50/p99/p999 response time per offered load, which
  explodes past the knee while staying near service time below it;
* **SLA misses + backpressure** — fraction of responses past the SLA and
  requests rejected by the bounded queue.

One shape bucket, one compile for the whole figure (every protocol and
load level is traced state; asserted in the emitted ``compiles`` row).
"""
from .common import _SWEEP_STATS, emit, sweep_stats
from repro.core.lock import CostModel, WorkloadSpec
from repro.core.lock.metrics import TICKS_PER_SEC
from repro.serving import ServeCell, poisson, pool_capacity_tps, serve

# op 0 hits THE hot row: the contention regime where queue/ordered
# locking separate from detection-based 2PL (fig02's motivation workload,
# two ops deep so lock order matters)
HOT = WorkloadSpec(kind="hotspot_update", txn_len=2, n_rows=4096)
CM = CostModel()
PROTOCOLS = ("mysql", "group", "brook2pl")
SLA_US = 2_000.0


def build_cells(quick: bool):
    T = 32
    horizon = 240_000 if quick else 1_200_000
    seg = horizon // 24
    # the load ladder is anchored at the UNCONTENDED mysql capacity; the
    # hotspot knees sit at ~0.02 (mysql) to ~0.12 (brook) of it, so the
    # ladder brackets every protocol's knee from below and above
    rhos = (0.01, 0.05, 0.25, 1.0) if quick else (
        0.005, 0.01, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0)
    cap = pool_capacity_tps(HOT, CM, T, "mysql")        # tps
    cells = []
    for proto in PROTOCOLS:
        for rho in rhos:
            rate = rho * cap / TICKS_PER_SEC            # arrivals per tick
            # per-slot credit must cover a segment's worth of service or
            # the quota (not the protocol) becomes the bottleneck
            cells.append(ServeCell(
                name=f"fig17_{proto}_rho{rho}",
                schedule=poisson(rate, horizon, seed=17),
                workload=HOT, n_threads=T, preset=proto, costs=CM,
                queue_cap=8 * T, admission="reject",
                max_outstanding=max(8, int(2 * seg * rate / T) + 1),
                sla_us=SLA_US))
    return cells, rhos, seg


def run(quick=True):
    cells, rhos, seg = build_cells(quick)
    res = serve(cells, seg_ticks=seg)
    _SWEEP_STATS.append(sweep_stats(res))
    rows = []
    for c in cells:
        s = res.serving[c.name]
        rows.append(
            f"{c.name},{res.wall_us[c.name]:.0f},"
            f"offered_tps={s.offered_tps:.0f}"
            f";goodput_tps={s.goodput_tps:.0f}"
            f";completed_tps={s.completed_tps:.0f}"
            f";p50_us={s.p50_us:.1f};p99_us={s.p99_us:.1f}"
            f";p999_us={s.p999_us:.1f}"
            f";sla_miss_frac={s.sla_miss_frac:.3f}"
            f";rejected={s.rejected};qlen_end={s.qlen_end}"
            f";util={s.utilization:.3f}")
    # knee summary: peak delivered goodput per protocol across the ladder
    knees = {}
    for proto in PROTOCOLS:
        knees[proto] = max(res.serving[f"fig17_{proto}_rho{r}"].goodput_tps
                           for r in rhos)
    best = max(knees, key=knees.get)
    rows.append(
        "fig17_knee,0,"
        + ";".join(f"{p}_knee_tps={v:.0f}" for p, v in knees.items())
        + f";best={best};compiles={res.n_compiles}")
    return emit(rows)


if __name__ == "__main__":
    run()
